"""Fleet serving demo: N real engine replicas under the paper's hybrid
offline-online scheduler at replica granularity.

Builds a 2-replica fleet over ONE set of model weights (each replica owns
an independent paged KV pool), serves a skewed workload three ways —

  * ``round_robin``       — round_robin_assign partition + round-robin
                            dispatch, no stealing (the unbalanced baseline);
  * ``lpt``               — solve_offline (LPT + local search) partition +
                            least-estimated-load dispatch + work stealing
                            (the full hybrid);
  * ``lpt/no-steal``      — ablation: balanced partition, stealing off.

— and prints the fleet report (makespan, fleet utilization vs the flat-pool
``theoretical_lower_bound``, steal events) plus per-replica Gantt rows on a
shared time axis, where round-robin's straggler replica shows up as a tail
of idle columns.

A second, HETEROGENEOUS round then emulates a mixed-generation fleet on
this one host (``ReplicaSpec.speed_factor`` scales each replica's
virtual-time stage clock — the 0.33× replica's Gantt rows are visibly
denser, the same tokens stretched over more of the shared axis) and
compares the R||Cmax-aware partition (``assign="lpt"``) against the
speed-blind P||Cmax one (``assign="lpt_blind"``).

A final FAILURE-RECOVERY round kills replica 0 mid-serve via a
``FaultPlan``: the fleet re-queues its queued and in-flight requests onto
the survivor (recompute-on-resume), every request completes exactly once
with token streams bit-identical to the no-fault serve, and the Gantt shows
replica 0's rows going idle at the kill instant while the survivor's tail
stretches to absorb the load (goodput before/after printed).

Dispatch-policy flags live on ``FleetConfig``: ``assign`` ("lpt" |
"lpt_blind" | "round_robin"), ``dispatch`` ("least_load" | "round_robin"),
``work_stealing`` (bool), ``n_replicas``; per-replica speeds/cost priors
ride on ``Fleet(replica_specs=[ReplicaSpec(...), ...])``.

    PYTHONPATH=src python examples/serve_fleet.py
"""
import jax

from repro.configs.base import ArchConfig
from repro.core import CostModel, LagrangianPolicy, ReplicaSpec, Request
from repro.core.gantt import fleet_ascii_gantt
from repro.models.layers import init_params
from repro.models.transformer import TransformerLM
from repro.serving.engine import EngineConfig
from repro.serving.fleet import FaultPlan, Fleet, FleetConfig, ReplicaFault


def skewed_workload():
    """Decode-heavy requests at every other position — adversarial for a
    round-robin split over 2 replicas (they all land on replica 0)."""
    reqs = []
    for rid in range(12):
        if rid % 2 == 0 and rid < 8:
            reqs.append(Request(rid=rid, n_prefill=24, n_decode=64))
        else:
            reqs.append(Request(rid=rid, n_prefill=16, n_decode=8))
    return reqs


def main():
    cfg = ArchConfig(
        name="demo-120m", family="dense", n_layers=4, d_model=256, n_heads=8,
        n_kv_heads=4, d_ff=512, vocab_size=1024,
    )
    model = TransformerLM(cfg)
    params = init_params(jax.random.key(0), model.param_defs())
    cm = CostModel(level_caps=(32, 64, 128, 256))
    ecfg = EngineConfig(
        n_slots=4, max_len=128, prefill_seq_buckets=(32,),
        kv_layout="paged", page_size=16, prefill_chunk=32,
    )

    modes = {
        "round_robin": FleetConfig(
            n_replicas=2, assign="round_robin", dispatch="round_robin",
            work_stealing=False,
        ),
        "lpt": FleetConfig(n_replicas=2, assign="lpt", dispatch="least_load"),
        "lpt/no-steal": FleetConfig(
            n_replicas=2, assign="lpt", dispatch="least_load",
            work_stealing=False,
        ),
    }
    for name, fc in modes.items():
        fleet = Fleet(model, params, ecfg, fc, cost_model=cm)
        fleet.serve(skewed_workload(), LagrangianPolicy)    # warm (compiles)
        report = fleet.serve(skewed_workload(), LagrangianPolicy)
        s = report.summary()
        print(
            f"{name:14s} makespan={s['makespan_s']:7.3f}s  "
            f"fleet util={s['fleet_utilization'] * 100:5.1f}%  "
            f"speed={s['generation_speed_tok_s']:7.0f} tok/s  "
            f"lb_ratio={s['lb_ratio']:.2f}  steals={s['steal_events']}  "
            f"replica makespans={s['replica_makespans_s']}"
        )
        print(fleet_ascii_gantt(report, width=84))

    # ---- heterogeneous fleet: one replica at a third of the speed ------- #
    print("== heterogeneous fleet (speeds x1.0 / x0.33) ==")
    specs = [ReplicaSpec(speed_factor=1.0), ReplicaSpec(speed_factor=0.33)]
    het_modes = {
        "hetero lpt": FleetConfig(
            n_replicas=2, assign="lpt", dispatch="least_load",
            work_stealing=False,
        ),
        "blind lpt": FleetConfig(
            n_replicas=2, assign="lpt_blind", dispatch="least_load",
            work_stealing=False,
        ),
    }
    for name, fc in het_modes.items():
        fleet = Fleet(model, params, ecfg, fc, cost_model=cm,
                      replica_specs=specs)
        fleet.warm_serving_shapes()          # compile before profiled stages
        report = fleet.serve(skewed_workload(), LagrangianPolicy)
        s = report.summary()
        print(
            f"{name:14s} makespan={s['makespan_s']:7.3f}s  "
            f"fleet util={s['fleet_utilization'] * 100:5.1f}% "
            f"(speed-weighted)  solver={s['offline_solver']}  "
            f"replica requests={s['replica_requests']}"
        )
        print(fleet_ascii_gantt(report, width=84))

    # ---- failure recovery: replica 0 dies halfway through the serve ----- #
    print("== failure recovery (replica 0 killed at t = 50% of no-fault) ==")
    fc = FleetConfig(n_replicas=2, assign="lpt", dispatch="least_load")
    fleet = Fleet(model, params, ecfg, fc, cost_model=cm)
    fleet.serve(skewed_workload(), LagrangianPolicy)        # warm (compiles)
    for eng in fleet.engines:
        eng.warm_serving_shapes()     # post-kill admission shapes too
    base = fleet.serve(skewed_workload(), LagrangianPolicy)
    base_gen = {rid: list(t) for rid, t in fleet.generated.items()}

    kill_at = 0.5 * base.makespan
    report = fleet.serve(
        skewed_workload(), LagrangianPolicy,
        fault_plan=FaultPlan([ReplicaFault(replica=0, at_s=kill_at)]),
    )
    done = [r for t in report.traces for r in t.requests]
    identical = fleet.generated.keys() == base_gen.keys() and all(
        fleet.generated[rid] == base_gen[rid] for rid in base_gen
    )
    print(
        f"killed replica 0 at t={kill_at:.3f}s: "
        f"completed={len(done)}/12 exactly-once="
        f"{len({r.rid for r in done}) == len(done)}  "
        f"recovered={fleet.recovered_requests}  "
        f"streams bit-identical to no-fault={identical}"
    )
    print(
        f"goodput before fault={base.goodput:7.0f} tok/s  "
        f"after fault={report.goodput:7.0f} tok/s  "
        f"makespan {base.makespan:.3f}s -> {report.makespan:.3f}s "
        f"(survivor absorbs the dead replica's queued + in-flight work; "
        f"replica 0's Gantt rows go idle past the kill instant)"
    )
    print(fleet_ascii_gantt(report, width=84))


if __name__ == "__main__":
    main()
