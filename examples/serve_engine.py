"""End-to-end driver: serve a small model with batched requests.

Runs the REAL continuous-batching engine (jitted prefill/decode of an actual
transformer on this machine) under the vLLM-style baseline and the paper's
hybrid scheduler, with the online profiler calibrating the cost model live —
the whole paper stack against real compute.

Decode is *fused*: ``EngineConfig.max_decode_horizon`` (default 8) lets the
policy commit up to K decode iterations to one on-device dispatch — sampling
included — instead of one host round-trip per token; pass
``decode_horizon=K`` to pin the horizon, or ``max_decode_horizon=1`` for the
per-token baseline. Token streams are identical either way (the per-mode
``dispatches/token`` column is what changes).

The paged modes contrast the two scheduling shapes: ``hybrid-paged-alt``
alternates prefill chunk rounds with decode stages (decoders freeze behind
every chunk — the ``stall`` column), while ``hybrid-paged`` (mixed-step, the
default) co-dispatches prefill chunks inside decode rounds under the
policy's ``prefill_share`` pricing, so the stall is ~0 and stages show as
'M' in the Gantt. Token streams are identical across all modes.

    PYTHONPATH=src python examples/serve_engine.py
"""
import jax

from repro.configs.base import ArchConfig
from repro.core import (
    CostModel,
    GlobalQueueScheduler,
    LagrangianPolicy,
    PrefillFirstPolicy,
    SortingPreemptiveScheduler,
    build_clients,
    solve_offline,
)
from repro.core.gantt import ascii_gantt
from repro.data import WorkloadSpec, gsm8k_like_workload, shared_prefix_workload
from repro.models.layers import init_params
from repro.models.transformer import TransformerLM
from repro.obs import (
    Observation,
    capacity_table,
    check_capacity_conservation,
    lifecycle_table,
    perfetto_trace,
    write_trace,
)
from repro.serving.engine import Engine, EngineConfig


def main():
    cfg = ArchConfig(
        name="demo-120m", family="dense", n_layers=4, d_model=256, n_heads=8,
        n_kv_heads=4, d_ff=512, vocab_size=1024,
    )
    model = TransformerLM(cfg)
    params = init_params(jax.random.key(0), model.param_defs())
    spec = WorkloadSpec(
        n_requests=32, input_mean=24, input_std=8, output_mean=32,
        output_std=14, output_max=64, input_max=32,
    )
    cm = CostModel(level_caps=(32, 64, 128, 256))

    for mode in ("baseline", "hybrid", "hybrid-paged-alt", "hybrid-paged"):
        reqs = gsm8k_like_workload(spec, seed=7, known_lengths=True)
        if mode == "hybrid-paged":
            layout = dict(kv_layout="paged", page_size=16, prefill_chunk=32)
        elif mode == "hybrid-paged-alt":
            layout = dict(
                kv_layout="paged", page_size=16, prefill_chunk=32,
                mixed_schedule=False,
            )
        else:
            layout = {}
        eng = Engine(
            model, params,
            EngineConfig(
                n_slots=8, max_len=128, prefill_seq_buckets=(32,), **layout
            ),
        )
        eng.profiler.cost_model = cm
        if mode == "baseline":
            clients = build_clients(8, reqs, None)
            sched, pol = GlobalQueueScheduler(reqs), PrefillFirstPolicy()
        else:
            asn = solve_offline(reqs, 8, cm).assignment
            clients = build_clients(8, reqs, asn)
            sched, pol = SortingPreemptiveScheduler(clients), LagrangianPolicy()
        tr = eng.serve(reqs, clients, sched, pol, policy_name=mode)
        s = tr.summary()
        kv = (
            f"  peak KV={eng.slots.peak_kv_bytes() / 1024:.0f} KiB"
            if mode.startswith("hybrid-paged") else ""
        )
        dpt = eng.decode_dispatches / max(eng.decoded_tokens, 1)
        print(
            f"{mode:16s} util={s['utilization'] * 100:5.1f}%  "
            f"wall={s['makespan_s']:6.2f}s  speed={s['generation_speed_tok_s']:6.0f} tok/s  "
            f"prefill stages={s['num_bins']}  dispatches/token={dpt:.3f}  "
            f"mixed rounds={s['mixed_rounds']}  "
            f"stall={s['prefill_stall_time_s']:.3f}s  "
            f"profiler refits={eng.profiler.fits}{kv}"
        )
        print(ascii_gantt(tr, width=90, max_clients=8))

    # shared-prefix demo: the same prompts through the refcounted prefix
    # cache — members of a hot template group adopt the published KV pages
    # read-only and only compute their unique tails (COW at divergence).
    # Token streams must not change; only the computed/cached split does.
    print("shared-prefix demo (3 Zipf-hot templates, prefix cache off vs on):")
    gens = {}
    for cache_on in (False, True):
        reqs = shared_prefix_workload(
            spec, seed=7, n_groups=3, prefix_mean=20.0, prefix_std=4.0,
            known_lengths=True,
        )
        eng = Engine(
            model, params,
            EngineConfig(
                n_slots=8, max_len=128, prefill_seq_buckets=(32,),
                kv_layout="paged", page_size=16, prefill_chunk=32,
                prefix_cache=cache_on,
            ),
        )
        eng.profiler.cost_model = cm
        tr = eng.serve(
            reqs, build_clients(8, reqs, None), GlobalQueueScheduler(reqs),
            PrefillFirstPolicy(),
            policy_name="cache-on" if cache_on else "cache-off",
        )
        gens[cache_on] = dict(eng.generated)
        total = sum(r.n_prefill for r in reqs)
        print(
            f"  prefix cache {'on ' if cache_on else 'off'}: "
            f"computed prefill={tr.computed_prefill_tokens:4d} tok  "
            f"cached={tr.cached_prefill_tokens:4d} tok  "
            f"hit-rate={eng.cache_hit_tokens / total * 100:4.1f}%  "
            f"shared pages peak={eng.slots.shared_pages_peak}  "
            f"cow copies={eng.slots.cow_copies}"
        )
        if cache_on:
            print(ascii_gantt(tr, width=90, max_clients=8))
    print(f"token streams identical across cache off/on: {gens[False] == gens[True]}")

    # observability demo: the same mixed-step serve with an Observation
    # attached — per-request lifecycle spans, the capacity-attribution
    # rollup (every slot-second classified, rows summing exactly to
    # makespan x slots), and a Perfetto trace for ui.perfetto.dev.
    print("observability demo (hybrid-paged serve, observe=Observation()):")
    obs = Observation()
    reqs = gsm8k_like_workload(spec, seed=7, known_lengths=True)
    eng = Engine(
        model, params,
        EngineConfig(
            n_slots=8, max_len=128, prefill_seq_buckets=(32,),
            kv_layout="paged", page_size=16, prefill_chunk=32, observe=obs,
        ),
    )
    eng.profiler.cost_model = cm
    eng.serve(
        reqs, build_clients(8, reqs, None), GlobalQueueScheduler(reqs),
        LagrangianPolicy(), policy_name="observed",
    )
    check_capacity_conservation(obs)
    print(capacity_table(obs))
    print("first 3 request lifecycles:")
    print(lifecycle_table(obs, rids=[0, 1, 2]))
    path = write_trace(obs, "serve_engine.trace.json")
    n_events = len(perfetto_trace(obs)["traceEvents"])
    print(
        f"wrote {path} ({n_events} events, "
        f"{len(obs.audit.records)} audit records) — open in ui.perfetto.dev"
    )


if __name__ == "__main__":
    main()
