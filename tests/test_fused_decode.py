"""Fused on-device multi-step decode: exact token parity with the per-token
baseline (greedy and seeded top-p, dense and paged layouts), mid-horizon stop
handling (budget and EOS), checkpoint at a horizon boundary, horizon pricing,
and the per-horizon cost-model fit."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core import (
    CostModel,
    GlobalQueueScheduler,
    LagrangianPolicy,
    PrefillFirstPolicy,
    build_clients,
)
from repro.core.iteration import CandidateBatch, SystemSnapshot
from repro.core.types import Request
from repro.data import WorkloadSpec, gsm8k_like_workload
from repro.models.layers import init_params
from repro.models.transformer import TransformerLM
from repro.serving.engine import Engine, EngineConfig
from repro.serving.profiler import OnlineProfiler
from repro.serving.sampler import GreedySampler, TopPSampler, fold_row_keys, greedy

CFG = ArchConfig(
    name="demo", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=256,
)
# mixed prompt/decode lengths so slots hit their stop conditions at
# different iterations inside a shared horizon
SPEC = WorkloadSpec(
    n_requests=10, input_mean=18, input_std=6, output_mean=12,
    output_std=8, output_max=24, input_max=28,
)
CM = CostModel(level_caps=(32, 64, 128))


@pytest.fixture(scope="module")
def model_and_params():
    model = TransformerLM(CFG)
    params = init_params(jax.random.key(0), model.param_defs())
    return model, params


def _engine(model, params, horizon, layout="dense", sampler=greedy, **kw):
    if layout == "paged":
        kw.setdefault("page_size", 16)
        kw.setdefault("prefill_chunk", 24)
        kw.setdefault("num_pages", 16)
    eng = Engine(
        model, params,
        EngineConfig(
            n_slots=4, max_len=64, prefill_seq_buckets=(32,),
            kv_layout=layout, decode_horizon=horizon, **kw,
        ),
        sampler=sampler,
    )
    eng.profiler.cost_model = CM
    return eng


def _serve(eng, seed=0):
    reqs = gsm8k_like_workload(SPEC, seed=seed, known_lengths=True)
    clients = build_clients(4, reqs, None)
    tr = eng.serve(reqs, clients, GlobalQueueScheduler(reqs), PrefillFirstPolicy())
    tr.validate()
    return tr


# --------------------------------------------------------------------------- #
# Token-stream parity: fused K vs per-token baseline                          #
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("layout", ["dense", "paged"])
@pytest.mark.slow
def test_fused_greedy_matches_per_token(model_and_params, layout):
    model, params = model_and_params
    base = _engine(model, params, horizon=1, layout=layout)
    _serve(base)
    fused = _engine(model, params, horizon=8, layout=layout)
    _serve(fused)
    assert base.generated.keys() == fused.generated.keys()
    for rid in base.generated:
        assert base.generated[rid] == fused.generated[rid], f"rid {rid}"
    # the point of the subsystem: ≤ ⌈1/K⌉ host syncs per decoded token
    # (each dispatch syncs exactly once, at its horizon boundary)
    assert fused.decode_dispatches < base.decode_dispatches
    assert fused.decode_dispatches / fused.decoded_tokens < 0.3


@pytest.mark.parametrize("layout", ["dense", "paged"])
@pytest.mark.slow
def test_fused_seeded_top_p_matches_per_token(model_and_params, layout):
    model, params = model_and_params
    samp = TopPSampler(top_p=0.95)
    runs = {}
    for k in (1, 8):
        eng = _engine(
            model, params, horizon=k, layout=layout, sampler=samp, sample_seed=3
        )
        _serve(eng)
        runs[k] = eng.generated
    assert runs[1].keys() == runs[8].keys()
    for rid in runs[1]:
        assert runs[1][rid] == runs[8][rid], f"rid {rid}"


@pytest.mark.slow
def test_stream_is_pure_function_of_seed_and_rid(model_and_params):
    """Dense vs paged, K=1 vs K=8, same seed → identical streams; different
    seed → different streams (the (seed, rid, token_index) key contract)."""
    model, params = model_and_params
    samp = TopPSampler(top_p=0.95)
    a = _engine(model, params, horizon=8, layout="dense", sampler=samp, sample_seed=3)
    _serve(a)
    b = _engine(model, params, horizon=4, layout="paged", sampler=samp, sample_seed=3)
    _serve(b)
    c = _engine(model, params, horizon=8, layout="dense", sampler=samp, sample_seed=4)
    _serve(c)
    for rid in a.generated:
        assert a.generated[rid] == b.generated[rid]
    assert any(a.generated[r] != c.generated[r] for r in a.generated)


def test_fused_ring_cache_matches_per_token(model_and_params):
    """Sliding-window (ring cache) dense path through the fused loop."""
    cfg = ArchConfig(
        name="swa", family="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=256, sliding_window=24,
    )
    model = TransformerLM(cfg)
    params = init_params(jax.random.key(1), model.param_defs())
    base = _engine(model, params, horizon=1)
    _serve(base)
    fused = _engine(model, params, horizon=4)
    _serve(fused)
    for rid in base.generated:
        assert base.generated[rid] == fused.generated[rid], f"rid {rid}"


# --------------------------------------------------------------------------- #
# Mid-horizon stops                                                           #
# --------------------------------------------------------------------------- #
def test_slot_stopping_mid_horizon_is_noop_not_early_exit(model_and_params):
    """A short request exhausting its budget mid-horizon must freeze (no KV
    write, no length growth) while its batch-mates keep decoding — and the
    long request's stream must equal the per-token baseline's."""
    model, params = model_and_params

    def run(k):
        reqs = [
            Request(rid=0, n_prefill=8, n_decode=2),    # stops at iteration 1
            Request(rid=1, n_prefill=9, n_decode=14),   # spans two horizons
        ]
        eng = _engine(model, params, horizon=k)
        clients = build_clients(4, reqs, None)
        tr = eng.serve(
            reqs, clients, GlobalQueueScheduler(reqs), PrefillFirstPolicy()
        )
        tr.validate()
        return eng, tr

    base, _ = run(1)
    fused, tr = run(8)
    assert fused.generated[0] == base.generated[0]
    assert fused.generated[1] == base.generated[1]
    assert len(fused.generated[0]) == 2 and len(fused.generated[1]) == 14
    # both requests decoded inside far fewer dispatches than tokens
    decode_stages = [s for s in tr.stages if s.kind.value == "decode"]
    assert len(decode_stages) < 14
    # a fused stage emits fewer tokens than rounds × slots once rid 0 stops
    assert any(s.tokens < s.rounds * len(s.busy) for s in decode_stages)


def test_eos_mid_horizon_stops_stream(model_and_params):
    """With eos_id set, a slot sampling EOS mid-horizon must stop exactly
    there — the stream equals the no-EOS stream truncated after the EOS."""
    model, params = model_and_params
    req = Request(rid=0, n_prefill=8, n_decode=12)
    # reference stream without EOS handling
    base = _engine(model, params, horizon=1)
    clients = build_clients(4, [req], None)
    base.serve([req], clients, GlobalQueueScheduler([req]), PrefillFirstPolicy())
    stream = base.generated[0]
    eos = stream[5]                     # force a stop 6 tokens in
    cut = stream.index(eos)             # first occurrence is where it stops

    req2 = Request(rid=0, n_prefill=8, n_decode=12)
    eng = _engine(model, params, horizon=8, eos_id=int(eos))
    clients2 = build_clients(4, [req2], None)
    eng._run_prefill_stage([(clients2[0], req2)])
    _, finished, _ = eng._run_decode_stage(8)
    assert eng.generated[0] == stream[: cut + 1]
    assert finished == [0]


# --------------------------------------------------------------------------- #
# Checkpoint at a horizon boundary                                            #
# --------------------------------------------------------------------------- #
def test_checkpoint_restore_at_horizon_boundary(model_and_params, tmp_path):
    from repro.checkpoint import restore_checkpoint, save_checkpoint

    model, params = model_and_params
    reqs = [
        Request(rid=0, n_prefill=8, n_decode=12),
        Request(rid=1, n_prefill=6, n_decode=12),
    ]
    eng = _engine(model, params, horizon=4)
    clients = build_clients(4, reqs, None)
    eng._run_prefill_stage([(clients[0], reqs[0]), (clients[1], reqs[1])])
    eng._run_decode_stage(4)                      # horizon boundary
    state = eng.state_dict()
    save_checkpoint(tmp_path, 1, state)

    eng._run_decode_stage(4)                      # original continues

    eng2 = _engine(model, params, horizon=4)
    restored, _ = restore_checkpoint(tmp_path, 1, eng2.state_dict())
    eng2.load_state_dict(restored, {r.rid: r for r in reqs})
    assert eng2.slots.emitted == [5, 5, 0, 0]     # 1 prefill + 4 decode tokens
    eng2._run_decode_stage(4)                     # restored continues

    # the restored engine's post-boundary tokens == the original's
    for rid in (0, 1):
        assert eng2.generated[rid] == eng.generated[rid][5:9]
    for a, b in zip(
        jax.tree_util.tree_leaves(eng.slots.cache),
        jax.tree_util.tree_leaves(eng2.slots.cache),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------------- #
# Samplers                                                                    #
# --------------------------------------------------------------------------- #
def test_sampler_objects_jit_and_key_threading():
    logits = jnp.asarray(np.random.default_rng(0).normal(size=(3, 32)) * 3)
    g = GreedySampler()
    np.testing.assert_array_equal(
        np.asarray(g(logits)), np.argmax(np.asarray(logits), axis=-1)
    )
    base = jax.random.key(0)
    rids = jnp.asarray([7, 7, 9], jnp.int32)
    steps = jnp.asarray([0, 1, 0], jnp.int32)
    keys = fold_row_keys(base, rids, steps)
    t = TopPSampler(top_p=0.9)
    a = np.asarray(t(logits, keys))
    b = np.asarray(jax.jit(t)(logits, keys))      # jit-composable, same draw
    np.testing.assert_array_equal(a, b)
    with pytest.raises(ValueError, match="per-row PRNG keys"):
        t(logits)
    # near-degenerate nucleus → the argmax token
    tiny = TopPSampler(top_p=1e-6)
    np.testing.assert_array_equal(
        np.asarray(tiny(logits, keys)), np.argmax(np.asarray(logits), axis=-1)
    )


# --------------------------------------------------------------------------- #
# Horizon pricing + per-horizon cost model                                    #
# --------------------------------------------------------------------------- #
def _snap(pending, n_active=4, n_clients=4, n_cand=0):
    cand = [Request(rid=i, n_prefill=4, n_decode=4) for i in range(n_cand)]
    return SystemSnapshot(
        n_clients=n_clients, n_active=n_active, n_idle=n_clients - n_active,
        active_remaining_est=64, pending_requests=pending,
        candidate=CandidateBatch(requests=cand, client_ids=list(range(n_cand))),
        now=0.0,
    )


def test_policy_horizon_pricing():
    pol = LagrangianPolicy()
    cm = CostModel(level_caps=(64,))
    # no pending work → nothing to preempt for → saturate the horizon
    assert pol.decode_horizon(_snap(pending=0), cm, k_max=16) == 16
    # a drained queue but a live candidate (e.g. a long prompt's remaining
    # chunks) is still preemptible work — the horizon must stay priced
    assert pol.decode_horizon(_snap(pending=0, n_cand=2), cm, k_max=16) < 16
    # heavy admission pressure → per-iteration granularity
    k_hot = pol.decode_horizon(_snap(pending=100), cm, k_max=16)
    # dispatch cost dominating the round time → fuse deeper
    cm_slow_dispatch = CostModel(decode_dispatch=0.5, level_caps=(64,))
    k_deep = pol.decode_horizon(_snap(pending=100), cm_slow_dispatch, k_max=16)
    assert k_deep > k_hot
    assert 1 <= k_hot <= k_deep <= 16
    # k_max=1 is the hard per-token cap
    assert pol.decode_horizon(_snap(pending=0), cm, k_max=1) == 1


def test_cost_model_fused_fit_recovers_dispatch():
    true = CostModel(
        prefill_per_token=2e-3, prefill_overhead=5e-3,
        decode_per_token=1e-3, decode_overhead=4e-3, decode_dispatch=3e-3,
        level_caps=(64, 128),
    )
    prefill = [(n, true.prefill_time(n)) for n in (16, 32, 64)]
    decode = [
        (n, k, true.fused_decode_time(n, k))
        for n in (2, 4, 8) for k in (1, 2, 4, 8)
    ]
    fit = CostModel.fit(prefill, decode, level_caps=(64, 128))
    assert fit.decode_dispatch == pytest.approx(3e-3, rel=1e-6)
    assert fit.decode_overhead == pytest.approx(4e-3, rel=1e-6)
    assert fit.decode_per_token == pytest.approx(1e-3, rel=1e-6)
    # single-horizon samples: dispatch not identifiable → prior retained,
    # per-round model still fit (the paper's 2-parameter calibration)
    fit2 = CostModel.fit(
        prefill, [(n, 1, true.fused_decode_time(n, 1)) for n in (2, 4, 8)],
        level_caps=(64, 128), decode_dispatch=7e-3,
    )
    assert fit2.decode_dispatch == pytest.approx(7e-3)
    assert fit2.decode_per_token == pytest.approx(1e-3, rel=1e-6)


def test_profiler_learns_per_horizon_timings():
    prof = OnlineProfiler(initial=CostModel(level_caps=(64, 128)), refit_every=4)
    true = CostModel(
        prefill_per_token=2e-3, prefill_overhead=5e-3,
        decode_per_token=1e-3, decode_overhead=4e-3, decode_dispatch=6e-3,
        level_caps=(64, 128),
    )
    for n, k in ((2, 1), (4, 2), (8, 4), (2, 8), (4, 1), (8, 8)):
        prof.record_prefill(16 * n, true.prefill_time(16 * n))
        prof.record_decode(n, true.fused_decode_time(n, k), rounds=k)
    assert prof.fits >= 1
    assert prof.cost_model.decode_dispatch == pytest.approx(6e-3, rel=1e-3)
    assert prof.cost_model.decode_per_token == pytest.approx(1e-3, rel=1e-3)
