"""Accounting regressions: the OfflineResult.gap degenerate-bound fix,
idle-gap-aware utilization (open-loop arrival gaps reported separately from
scheduler-caused idleness), and the fleet's assignment-evaluation helpers.
Kept hypothesis-free so the module always runs."""
import pytest

from repro.core import (
    CostModel,
    PAPER_COST_MODEL,
    make_requests,
    solve_offline,
)


def test_offline_gap_degenerate_lower_bound():
    """Regression: a zero LP bound used to report gap 0.0 — a 'perfect'
    solution — even when the achieved makespan was positive. Only
    zero-over-zero is a true 0.0; positive-over-zero is an infinite gap."""
    from repro.core import OfflineResult

    def result(makespan, lb):
        return OfflineResult(
            assignment=[[]], loads=[makespan], makespan_est=makespan,
            lp_lower_bound=lb, solver="test", solve_seconds=0.0,
        )

    assert result(0.0, 0.0).gap == 0.0
    assert result(1.5, 0.0).gap == float("inf")
    assert result(1.5, 1.0).gap == pytest.approx(0.5)
    # an empty instance solves to an empty, gapless assignment
    res = solve_offline([], 3, PAPER_COST_MODEL)
    assert res.makespan_est == 0.0 and res.gap == 0.0


def test_evaluate_assignment_matches_solver_diagnostics():
    from repro.core import evaluate_assignment, round_robin_assign

    reqs = make_requests([10, 10, 10, 10], [40, 5, 40, 5])
    asn = round_robin_assign(reqs, 2)
    res = evaluate_assignment(reqs, asn, 2, PAPER_COST_MODEL, solver="rr")
    ref = solve_offline(reqs, 2, PAPER_COST_MODEL)
    # same LP bound (instance property), worse-or-equal makespan than LPT
    assert res.lp_lower_bound == pytest.approx(ref.lp_lower_bound)
    assert res.makespan_est >= ref.makespan_est - 1e-12
    assert res.solver == "rr"
    assert sum(res.loads) == pytest.approx(sum(ref.loads))


def test_split_requests_partitions_exactly():
    from repro.core import split_requests

    reqs = make_requests([4, 5, 6, 7], [1, 2, 3, 4])
    parts = split_requests(reqs, [[2, 0], [1], [3]])
    assert [[r.rid for r in p] for p in parts] == [[2, 0], [1], [3]]
    with pytest.raises(ValueError):
        split_requests(reqs, [[0, 0], [1], [2, 3]])
    with pytest.raises(ValueError):
        split_requests(reqs, [[0], [1]])    # 2 and 3 unassigned


def test_utilization_accounts_idle_gaps_separately():
    """Regression: open-loop traces (engine fast-forwards over arrival
    gaps) used to fold forced-idle time into the only utilization number.
    Both views now exist: ``utilization`` (paper metric, gaps included)
    and ``busy_window_utilization`` (gaps excluded)."""
    from repro.core import ScheduleTrace, StageKind, StageRecord

    tr = ScheduleTrace(num_clients=2)
    tr.stages = [
        StageRecord(kind=StageKind.DECODE, t_start=0.0, t_end=1.0,
                    bin_index=0, busy={0: 0, 1: 1}, tokens=2, rounds=1),
        # 3-second arrival gap: nothing ran
        StageRecord(kind=StageKind.DECODE, t_start=4.0, t_end=5.0,
                    bin_index=0, busy={0: 2, 1: 3}, tokens=2, rounds=1),
    ]
    assert tr.makespan == 5.0
    assert tr.idle_gap_time == pytest.approx(3.0)
    assert tr.busy_window == pytest.approx(2.0)
    # gaps included: 4 busy client-seconds over 10 client-seconds
    assert tr.utilization == pytest.approx(0.4)
    # gaps excluded: 4 over 4
    assert tr.busy_window_utilization == pytest.approx(1.0)
    s = tr.summary()
    assert s["utilization"] == pytest.approx(0.4)
    assert s["busy_window_utilization"] == pytest.approx(1.0)
    assert s["idle_gap_s"] == pytest.approx(3.0)
    # closed-loop traces (no gaps): the two views agree exactly
    tr.stages[1].t_start, tr.stages[1].t_end = 1.0, 2.0
    assert tr.idle_gap_time == 0.0
    assert tr.busy_window_utilization == pytest.approx(tr.utilization)
    assert tr.busy_window_generation_speed == pytest.approx(
        tr.generation_speed
    )
