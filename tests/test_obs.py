"""Unified serve observability: typed metrics registry, causal lifecycle
spans, capacity-attribution conservation, Perfetto export, decision audit
log, the observe=None zero-callback guarantee, and obs state surviving a
fleet checkpoint round-trip."""
import json
import random

import jax
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core import (
    CostModel,
    GlobalQueueScheduler,
    LagrangianPolicy,
    Request,
    build_clients,
)
from repro.core.gantt import utilization_timeline
from repro.core.types import ScheduleTrace, StageKind, StageRecord
from repro.models.layers import init_params
from repro.models.transformer import TransformerLM
from repro.obs import (
    MetricDeclarationError,
    Observation,
    capacity_attribution,
    check_capacity_conservation,
    lifecycle_table,
    perfetto_trace,
    write_trace,
)
from repro.serving.engine import Engine, EngineConfig
from repro.serving.fleet import Fleet, FleetConfig

CFG = ArchConfig(
    name="demo", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=256,
)
CM = CostModel(level_caps=(32, 64, 128))
ENGINE_CFG = dict(
    n_slots=2, max_len=64, prefill_seq_buckets=(32,),
    kv_layout="paged", page_size=16, prefill_chunk=16,
)


@pytest.fixture(scope="module")
def model_and_params():
    model = TransformerLM(CFG)
    params = init_params(jax.random.key(0), model.param_defs())
    return model, params


def _requests(n=6, n_decode=10):
    return [Request(rid=i, n_prefill=10, n_decode=n_decode) for i in range(n)]


def _engine(model, params, **kw):
    eng = Engine(model, params, EngineConfig(**ENGINE_CFG, **kw))
    eng.profiler.cost_model = CM
    return eng


def _serve(eng, reqs):
    clients = build_clients(eng.cfg.n_slots, reqs, None)
    return eng.serve(reqs, clients, GlobalQueueScheduler(reqs),
                     LagrangianPolicy())


def _fleet(model, params, engine_kw=None, **fc_kw):
    fc_kw.setdefault("n_replicas", 2)
    fc_kw.setdefault("assign", "round_robin")
    fc_kw.setdefault("dispatch", "round_robin")
    fc_kw.setdefault("work_stealing", False)
    return Fleet(
        model, params, EngineConfig(**{**ENGINE_CFG, **(engine_kw or {})}),
        FleetConfig(**fc_kw), cost_model=CM,
    )


# --------------------------------------------------------------------------- #
# Typed metrics registry                                                      #
# --------------------------------------------------------------------------- #
def test_registry_duplicate_declaration_is_idempotent():
    obs = Observation()
    a = obs.declare("steal_events", "counter", unit="events", help="steals")
    b = obs.declare("steal_events", "counter", unit="events", help="steals")
    assert a == b
    obs.inc("steal_events", 2)
    assert obs.registry.scalars()["steal_events"] == 2.0


def test_registry_conflicting_redeclaration_raises():
    obs = Observation()
    obs.declare("queue_depth", "gauge", unit="requests")
    with pytest.raises(MetricDeclarationError):
        obs.declare("queue_depth", "counter", unit="requests")   # kind flip
    with pytest.raises(MetricDeclarationError):
        obs.declare("queue_depth", "gauge", unit="tokens")       # unit flip
    with pytest.raises(MetricDeclarationError):
        obs.declare("bogus", "trend")                            # unknown kind


def test_registry_scalars_exclude_log_side_channel():
    """Structured event records ride the typed log side-channel; the scalar
    export never smuggles them (the old meta dicts carried JSON strings)."""
    obs = Observation()
    obs.declare("lat", "histogram", unit="s")
    obs.observe_value("lat", 0.25)
    obs.observe_value("lat", 0.75)
    obs.set_log("fault_log", [{"replica": 1, "kind": "hang"}])
    obs.log("fault_log", {"replica": 0, "kind": "slow"})
    scalars = obs.registry.scalars()
    assert scalars["lat_count"] == 2.0 and scalars["lat_sum"] == 1.0
    assert all(isinstance(v, float) for v in scalars.values())
    assert obs.registry.logs["fault_log"] == [
        {"replica": 1, "kind": "hang"}, {"replica": 0, "kind": "slow"},
    ]


# --------------------------------------------------------------------------- #
# Capacity attribution: rows sum EXACTLY to makespan x slots                  #
# --------------------------------------------------------------------------- #
def test_capacity_conservation_on_engine_serve(model_and_params):
    model, params = model_and_params
    obs = Observation()
    eng = _engine(model, params, observe=obs)
    trace = _serve(eng, _requests())
    assert check_capacity_conservation(obs)
    rows = capacity_attribution(obs)
    assert set(rows) == {0}
    row = rows[0]
    assert row["capacity"] == pytest.approx(
        trace.makespan * eng.cfg.n_slots, rel=1e-9
    )
    assert row["busy"] > 0.0
    # lifecycle table renders every admitted request
    table = lifecycle_table(obs)
    for rid in range(6):
        assert f"\n{rid:5d}  " in table or table.startswith(f"{rid:5d}")


def test_capacity_conservation_on_fleet_serve(model_and_params):
    model, params = model_and_params
    obs = Observation()
    fleet = _fleet(model, params, engine_kw=dict(observe=obs))
    fleet.serve(_requests(8), LagrangianPolicy)
    assert check_capacity_conservation(obs)
    rows = capacity_attribution(obs)
    assert set(rows) == {0, 1}
    for row in rows.values():
        assert row["total"] == pytest.approx(row["capacity"], abs=1e-9)


# --------------------------------------------------------------------------- #
# Span parenting across a migration: one request, two replicas, one chain     #
# --------------------------------------------------------------------------- #
def test_span_chain_survives_forced_migration(model_and_params):
    model, params = model_and_params
    obs = Observation()
    fleet = _fleet(model, params, engine_kw=dict(observe=obs))
    # 2 requests over 2 round-robin replicas: replica 1 keeps a free slot
    # (and free pages) so the forced migration always has headroom
    reqs = _requests(2, n_decode=12)
    fleet.begin_serve(reqs, LagrangianPolicy)
    moved_rid = None
    while True:
        eng = fleet.engines[0]
        if moved_rid is None:
            for slot in list(eng.slots.active_slots):
                if eng.slots.emitted[slot] >= 3:
                    moved_rid = eng.slots.request_of[slot].rid
                    assert fleet.migrate_slot(0, slot, 1)
                    break
        if not fleet.step():
            break
    fleet.finish_serve()
    assert moved_rid is not None, "no slot ever reached 3 emitted tokens"

    evs = obs.spans.by_request(moved_rid)
    kinds = [e.kind for e in evs]
    assert "migrate_out" in kinds and "migrate_in" in kinds
    out_ev = next(e for e in evs if e.kind == "migrate_out")
    in_ev = next(e for e in evs if e.kind == "migrate_in")
    assert out_ev.replica == 0 and in_ev.replica == 1
    # the migrate_in on replica 1 is causally downstream of the migrate_out
    # on replica 0: walking parent links from the latest event reproduces
    # the full per-request history — one chain across the fleet
    assert in_ev.parent == out_ev.event_id
    assert obs.spans.chain(moved_rid) == evs
    assert evs[-1].kind == "complete"
    # every request's chain is intact, not just the migrated one
    for rid in obs.spans.request_ids():
        assert obs.spans.chain(rid) == obs.spans.by_request(rid)


# --------------------------------------------------------------------------- #
# Perfetto exporter: JSON schema                                              #
# --------------------------------------------------------------------------- #
def test_perfetto_trace_schema(model_and_params, tmp_path):
    model, params = model_and_params
    obs = Observation()
    eng = _engine(model, params, observe=obs)
    _serve(eng, _requests())
    path = write_trace(obs, str(tmp_path / "nested" / "serve.trace.json"))
    with open(path) as fh:
        doc = json.load(fh)
    events = doc["traceEvents"]
    assert events, "empty trace"
    phases = {e["ph"] for e in events}
    assert phases <= {"X", "i", "M"}
    assert "X" in phases and "M" in phases
    for e in events:
        assert isinstance(e["name"], str) and e["name"]
        assert isinstance(e["pid"], int) and isinstance(e["tid"], int)
        if e["ph"] == "X":
            assert e["ts"] >= 0.0 and e["dur"] >= 0.0
            assert "rid" in e["args"]
        elif e["ph"] == "i":
            assert e["s"] == "p" and e["ts"] >= 0.0
    # one named track per replica x slot plus the control lane
    threads = {
        (e["pid"], e["tid"]) for e in events
        if e["ph"] == "M" and e["name"] == "thread_name"
    }
    for slot in range(eng.cfg.n_slots):
        assert (0, slot) in threads
    assert doc["otherData"]["metrics"] == obs.registry.scalars()


# --------------------------------------------------------------------------- #
# observe=None executes ZERO observability callbacks                          #
# --------------------------------------------------------------------------- #
def test_observe_none_fires_no_obs_callbacks(model_and_params):
    model, params = model_and_params
    calls = []
    Observation.tripwire = staticmethod(lambda: calls.append(1))
    try:
        eng = _engine(model, params)                  # observe=None default
        _serve(eng, _requests())
        assert calls == [], (
            f"observe=None serve executed {len(calls)} obs callbacks"
        )
        # positive control: the tripwire does fire on an observed serve
        obs_eng = _engine(model, params, observe=Observation())
        _serve(obs_eng, _requests())
        assert len(calls) > 0
    finally:
        Observation.tripwire = None


# --------------------------------------------------------------------------- #
# Obs state rides the fleet checkpoint through tree_map(np.asarray)           #
# --------------------------------------------------------------------------- #
def test_fleet_checkpoint_roundtrips_obs_state(model_and_params):
    model, params = model_and_params
    obs = Observation()
    fleet = _fleet(model, params, engine_kw=dict(observe=obs))
    reqs = _requests(6)
    fleet.begin_serve(reqs, LagrangianPolicy)
    for _ in range(6):
        if not fleet.step():
            break
    state = jax.tree_util.tree_map(np.asarray, fleet.state_dict())

    obs2 = Observation()
    fleet2 = _fleet(model, params, engine_kw=dict(observe=obs2))
    fleet2.load_state_dict(state, {r.rid: r for r in _requests(6)})
    # recorded history restored: same events, same audit, same scalars
    assert len(obs2.spans.events) == len(obs.spans.events)
    assert [e.kind for e in obs2.spans.events] == [
        e.kind for e in obs.spans.events
    ]
    assert len(obs2.audit.records) == len(obs.audit.records)
    assert obs2.registry.scalars() == obs.registry.scalars()
    assert obs2.capacity_samples == obs.capacity_samples
    # the monitor's obs wiring survives restore (reset() used to drop it)
    if fleet2.monitor is not None:
        assert fleet2.monitor.obs is obs2
    while fleet2.step():
        pass
    report = fleet2.finish_serve()
    # summary() emits scalars, short string labels, and the per-replica
    # breakdown lists — never JSON strings. Serialized structures are what
    # the registry's typed log side-channel exists to replace.
    fleet_lists = {
        "speed_factors", "replica_makespans_s", "replica_requests",
        "replica_summaries",
    }
    for key, val in report.summary().items():
        if key in fleet_lists:
            assert isinstance(val, list)
            continue
        assert isinstance(val, (int, float, str)), f"{key} is {type(val)}"
        if isinstance(val, str):
            assert not val.lstrip().startswith(("[", "{")), (
                f"{key} smuggles JSON through summary(): {val[:60]!r}"
            )
    assert check_capacity_conservation(obs2)


# --------------------------------------------------------------------------- #
# Gantt utilization_timeline: bucket sums reconcile with total busy time      #
# --------------------------------------------------------------------------- #
def _random_trace(rng, n_stages, n_clients):
    t = 0.0
    stages = []
    for i in range(n_stages):
        dur = rng.choice([rng.uniform(1e-4, 0.5), rng.uniform(1e-9, 1e-6)])
        n_busy = rng.randint(0, n_clients)
        stages.append(StageRecord(
            kind=StageKind.DECODE, t_start=t, t_end=t + dur, bin_index=i,
            busy={c: c for c in range(n_busy)},
        ))
        t += dur
        if rng.random() < 0.3:
            t += rng.uniform(0.0, 0.2)    # idle gap between stages
            stages.append(StageRecord(
                kind=StageKind.DECODE, t_start=t, t_end=t, bin_index=i,
                busy={},
            ))
    return ScheduleTrace(num_clients=n_clients, stages=stages)


@pytest.mark.parametrize("seed", range(8))
def test_utilization_timeline_buckets_conserve_busy_time(seed):
    """Property: bucket shares x bucket capacity sum to exactly the trace's
    total busy client-time — a stage ending on a bucket edge cannot leak
    a sliver into the next bucket or drop one."""
    rng = random.Random(seed)
    trace = _random_trace(rng, n_stages=rng.randint(1, 30),
                          n_clients=rng.randint(1, 6))
    for buckets in (1, 7, 50):
        tl = utilization_timeline(trace, buckets)
        assert len(tl) == buckets
        span = trace.makespan
        if span <= 0:
            continue
        denom = span / buckets * trace.num_clients
        total_busy = sum(
            s.duration * (len(s.busy) + len(s.busy_partial))
            for s in trace.stages
        )
        # values are rounded to 4 decimals for display; allow exactly that
        tol = 5e-5 * buckets * denom + 1e-9
        assert sum(tl) * denom == pytest.approx(total_busy, abs=tol)


def test_utilization_timeline_edge_aligned_stages():
    """Stages tiling bucket edges exactly: every bucket reads 1.0."""
    stages = [
        StageRecord(kind=StageKind.DECODE, t_start=i * 0.1,
                    t_end=(i + 1) * 0.1, bin_index=i, busy={0: 0, 1: 1})
        for i in range(10)
    ]
    trace = ScheduleTrace(num_clients=2, stages=stages)
    tl = utilization_timeline(trace, 10)
    assert tl == [1.0] * 10


# --------------------------------------------------------------------------- #
# Decision audit log                                                          #
# --------------------------------------------------------------------------- #
def test_audit_log_records_dispatch_and_prefill_share(model_and_params):
    model, params = model_and_params
    obs = Observation()
    fleet = _fleet(model, params, engine_kw=dict(observe=obs),
                   dispatch="least_load")
    reqs = [Request(rid=i, n_prefill=10, n_decode=8,
                    arrival=0.0 if i < 4 else 0.01 * i) for i in range(8)]
    fleet.serve(reqs, LagrangianPolicy)
    counts = obs.audit.counts()
    # every online arrival produced exactly one priced dispatch record
    n_online = sum(1 for r in reqs if r.arrival > 0.0)
    assert counts.get("dispatch", 0) == n_online
    for rec in obs.audit.of_kind("dispatch"):
        assert rec.inputs["policy"] == "least_load"
        assert set(rec.inputs["loads_s"]) == {"0", "1"}
        assert rec.chosen in (0, 1)
