"""Live KV migration by page-copy: mid-request slot export/import parity
at every decode step, graceful drain with zero drops, soft-kill page-copy
recovery vs hard-kill recompute, in-flight rebalancing, fault-state
checkpoint round-trips, and the debug-invariants tripwire."""
import jax
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core import CostModel, LagrangianPolicy, Request
from repro.models.layers import init_params
from repro.models.transformer import TransformerLM
from repro.serving.engine import Engine, EngineConfig
from repro.serving.fleet import (
    FaultPlan,
    Fleet,
    FleetConfig,
    ReplicaFault,
    ReplicaSpec,
)
from repro.serving.sampler import TopPSampler, greedy

CFG = ArchConfig(
    name="demo", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=256,
)
CM = CostModel(level_caps=(32, 64, 128))
ENGINE_CFG = dict(
    n_slots=2, max_len=64, prefill_seq_buckets=(32,),
    kv_layout="paged", page_size=16, prefill_chunk=16,
    decode_horizon=1, mixed_schedule=False,
)


@pytest.fixture(scope="module")
def model_and_params():
    model = TransformerLM(CFG)
    params = init_params(jax.random.key(0), model.param_defs())
    return model, params


def _fleet(model, params, engine_kw=None, sampler=greedy, specs=None, **fc_kw):
    fc_kw.setdefault("n_replicas", 2)
    fc_kw.setdefault("assign", "round_robin")
    fc_kw.setdefault("dispatch", "round_robin")
    fc_kw.setdefault("work_stealing", False)
    return Fleet(
        model, params, EngineConfig(**{**ENGINE_CFG, **(engine_kw or {})}),
        FleetConfig(**fc_kw), cost_model=CM, sampler=sampler,
        replica_specs=specs,
    )


def _assert_no_leaks(fleet):
    """Every pool empty and consistent, host and device tables agreeing."""
    for eng in fleet.engines:
        assert eng.slots.allocator.num_used == 0, "orphaned pages"
        eng.slots.allocator.check_consistency()
        eng.slots.check_block_table_mirror()


def _serve_with_bound_migration(fleet, reqs, rid, emitted_at):
    """Manual fleet loop migrating ``rid`` off replica 0 the moment its
    bound slot has emitted exactly ``emitted_at`` tokens."""
    fleet.begin_serve(reqs, LagrangianPolicy)
    migrated = False
    while True:
        eng = fleet.engines[0]
        if not migrated:
            for slot in list(eng.slots.active_slots):
                if (eng.slots.request_of[slot].rid == rid
                        and eng.slots.emitted[slot] == emitted_at):
                    assert fleet.migrate_slot(0, slot, 1)
                    migrated = True
                    break
        if not fleet.step():
            break
    report = fleet.finish_serve()
    return report, migrated


# --------------------------------------------------------------------------- #
# Tentpole: page-copy parity at every decode step × pools × samplers          #
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("num_pages", [None, 8])
@pytest.mark.parametrize(
    "sampler", [greedy, TopPSampler(top_p=0.9)], ids=["greedy", "top_p"]
)
def test_bound_migration_parity_every_decode_step(
    model_and_params, num_pages, sampler
):
    model, params = model_and_params
    n_decode = 6

    def requests():
        # rid 0 → replica 0 (round-robin), rid 1 keeps replica 1 non-trivial
        return [
            Request(rid=0, n_prefill=10, n_decode=n_decode),
            Request(rid=1, n_prefill=8, n_decode=3),
        ]

    engine_kw = dict(num_pages=num_pages)
    base = _fleet(model, params, engine_kw=engine_kw, sampler=sampler)
    base.serve(requests(), LagrangianPolicy)           # warm
    base.serve(requests(), LagrangianPolicy)
    ref_gen = {rid: list(t) for rid, t in base.generated.items()}
    _assert_no_leaks(base)

    # a bound slot exists with emitted = 1 (right after prefill) through
    # n_decode - 1; at n_decode the slot is already released
    for e in range(1, n_decode):
        fleet = _fleet(model, params, engine_kw=engine_kw, sampler=sampler)
        report, migrated = _serve_with_bound_migration(
            fleet, requests(), rid=0, emitted_at=e
        )
        assert migrated, f"never saw rid 0 bound with emitted == {e}"
        report.validate()
        done = {r.rid for t in report.traces for r in t.requests}
        assert done == {0, 1}
        # zero recomputed tokens: the stream continued, nothing re-prefilled
        assert all(eng.recomputed_tokens == 0 for eng in fleet.engines)
        assert fleet.migration_events == 1
        assert report.meta["migration_events"] == 1.0
        assert report.meta["recomputed_tokens"] == 0.0
        assert fleet.generated == ref_gen, f"stream diverged at emitted={e}"
        _assert_no_leaks(fleet)
        # the request finished on the destination replica's trace
        assert 0 in {r.rid for r in report.traces[1].requests}


def test_mid_chunk_migration_parity(model_and_params):
    """A request migrated BETWEEN prefill chunks (kind='chunking') resumes
    its remaining chunks on the destination with an identical stream."""
    model, params = model_and_params

    def requests():
        # 40-token prompt at prefill_chunk=16 → 3 chunks on replica 0
        return [
            Request(rid=0, n_prefill=40, n_decode=5),
            Request(rid=1, n_prefill=8, n_decode=3),
        ]

    base = _fleet(model, params)
    base.serve(requests(), LagrangianPolicy)           # warm
    base.serve(requests(), LagrangianPolicy)
    ref_gen = {rid: list(t) for rid, t in base.generated.items()}

    fleet = _fleet(model, params)
    fleet.begin_serve(requests(), LagrangianPolicy)
    migrated = False
    while True:
        eng = fleet.engines[0]
        if not migrated:
            for slot, st in list(eng._chunking.items()):
                if st.req.rid == 0 and st.done > 0:
                    assert fleet.migrate_slot(0, slot, 1)
                    migrated = True
                    break
        if not fleet.step():
            break
    report = fleet.finish_serve()
    assert migrated, "never saw rid 0 between prefill chunks"
    report.validate()
    assert all(eng.recomputed_tokens == 0 for eng in fleet.engines)
    assert fleet.generated == ref_gen
    _assert_no_leaks(fleet)


def test_migrate_slot_refuses_without_headroom(model_and_params):
    """migrate_slot returns False (state untouched) when the destination
    has no free slot to bind the migrated request to."""
    model, params = model_and_params
    # one slot per replica: while rid 1 decodes on replica 1, its only
    # slot is taken and an import there must be refused
    fleet = _fleet(model, params, engine_kw=dict(n_slots=1))
    fleet.begin_serve(
        [Request(rid=0, n_prefill=10, n_decode=4),
         Request(rid=1, n_prefill=8, n_decode=30)],
        LagrangianPolicy,
    )
    probed = False
    while True:
        eng = fleet.engines[0]
        slots = list(eng.slots.active_slots)
        if slots and not probed and fleet.engines[1].slots.active_slots:
            assert not fleet.migrate_slot(0, slots[0], 1)
            assert eng.slots.request_of[slots[0]] is not None   # untouched
            assert fleet.migration_events == 0
            probed = True
        if not fleet.step():
            break
    assert probed, "rid 0 and rid 1 were never in flight simultaneously"
    fleet.finish_serve().validate()
    with pytest.raises(ValueError, match="coincide"):
        fleet.migrate_slot(0, 0, 0)


# --------------------------------------------------------------------------- #
# Graceful drain: zero drops, zero recompute                                  #
# --------------------------------------------------------------------------- #
def _drain_requests():
    # even rids (→ replica 0) decode-heavy; odd rids (→ replica 1) finish
    # fast, so at drain time the survivor has free slots and pool headroom
    out = []
    for rid in range(6):
        if rid % 2 == 0:
            out.append(Request(rid=rid, n_prefill=10, n_decode=20))
        else:
            out.append(Request(rid=rid, n_prefill=8, n_decode=2))
    return out


def _step_until_survivor_idle(fleet, min_emitted=1):
    """Step until replica 0 has a bound slot with >= min_emitted tokens
    while replica 1 has fully drained its own work (free slots + headroom
    for a page-copy). Returns False if the serve ended first."""
    while True:
        e0, e1 = fleet.engines
        ready = [
            s for s in e0.slots.active_slots
            if e0.slots.emitted[s] >= min_emitted
        ]
        if (ready and not e1.slots.active_slots and not e1._chunking
                and not e1._sv.scheduler.queued):
            return True
        if not fleet.step():
            return False


def test_drain_replica_zero_drops_zero_recompute(model_and_params):
    model, params = model_and_params
    base = _fleet(model, params)
    base.serve(_drain_requests(), LagrangianPolicy)    # warm
    base.serve(_drain_requests(), LagrangianPolicy)
    ref_gen = {rid: list(t) for rid, t in base.generated.items()}

    fleet = _fleet(model, params)
    fleet.serve(_drain_requests(), LagrangianPolicy)   # warm
    fleet.begin_serve(_drain_requests(), LagrangianPolicy)
    # drain at a deterministic instant: replica 0 mid-decode, survivor idle
    assert _step_until_survivor_idle(fleet)
    n_in_flight = len(fleet.engines[0].slots.active_slots)
    entry = fleet.drain_replica(0)
    while fleet.step():
        pass
    report = fleet.finish_serve()
    report.validate()
    done = [r for t in report.traces for r in t.requests]
    assert len(done) == 6 and all(r.t_done is not None for r in done)
    assert len({r.rid for r in done}) == 6             # zero drops
    assert fleet.generated == ref_gen                  # bit-identical
    # page-copy only: nothing re-prefilled anywhere in the fleet
    assert entry["page_copy"] == n_in_flight
    assert entry["recompute"] == 0
    assert report.meta["recomputed_tokens"] == 0.0
    assert report.meta["drained_replicas"] == 1.0
    assert report.meta["recovered_page_copy"] >= 1.0
    assert report.meta["recovered_recompute"] == 0.0
    _assert_no_leaks(fleet)


def test_drain_fault_plan_zero_drops(model_and_params):
    """kind='drain' in a FaultPlan: whatever instant the virtual clock
    crosses, every request still completes exactly once, bit-identically."""
    model, params = model_and_params
    base = _fleet(model, params)
    base.serve(_drain_requests(), LagrangianPolicy)    # warm
    ref = base.serve(_drain_requests(), LagrangianPolicy)
    ref_gen = {rid: list(t) for rid, t in base.generated.items()}

    fleet = _fleet(model, params)
    fleet.serve(_drain_requests(), LagrangianPolicy)   # warm
    report = fleet.serve(
        _drain_requests(), LagrangianPolicy,
        fault_plan=FaultPlan([
            ReplicaFault(replica=0, at_s=0.5 * ref.makespan, kind="drain"),
        ]),
    )
    report.validate()
    done = [r for t in report.traces for r in t.requests]
    assert len(done) == 6 and all(r.t_done is not None for r in done)
    assert len({r.rid for r in done}) == 6             # zero drops
    assert fleet.generated == ref_gen                  # bit-identical
    assert report.meta["drained_replicas"] == 1.0
    _assert_no_leaks(fleet)


def test_drain_replica_api_guards(model_and_params):
    model, params = model_and_params
    fleet = _fleet(model, params)
    fleet.begin_serve(_drain_requests(), LagrangianPolicy)
    for _ in range(4):
        fleet.step()
    fleet.drain_replica(0)
    with pytest.raises(ValueError, match="already retired"):
        fleet.drain_replica(0)
    with pytest.raises(RuntimeError, match="last alive"):
        fleet.drain_replica(1)
    while fleet.step():
        pass
    report = fleet.finish_serve()
    report.validate()
    assert {r.rid for t in report.traces for r in t.requests} == set(range(6))
    _assert_no_leaks(fleet)


# --------------------------------------------------------------------------- #
# Recovery: soft kill prefers page-copy, hard kill recomputes                 #
# --------------------------------------------------------------------------- #
def test_soft_kill_page_copy_beats_hard_kill_recompute(model_and_params):
    model, params = model_and_params
    base = _fleet(model, params)
    base.serve(_drain_requests(), LagrangianPolicy)    # warm
    base.serve(_drain_requests(), LagrangianPolicy)
    ref_gen = {rid: list(t) for rid, t in base.generated.items()}

    recomputed = {}
    for readable in (True, False):
        fleet = _fleet(model, params)
        fleet.serve(_drain_requests(), LagrangianPolicy)   # warm
        fleet.begin_serve(_drain_requests(), LagrangianPolicy)
        # kill at a deterministic instant: replica 0 has emitted >= 2
        # tokens on a bound slot (so a hard kill has a prefix to re-pay)
        # and the survivor can host a page-copy
        assert _step_until_survivor_idle(fleet, min_emitted=2)
        fleet._kill_replica(
            0, fleet.engines[0].clock, pool_readable=readable
        )
        while fleet.step():
            pass
        report = fleet.finish_serve()
        report.validate()
        done = {r.rid for t in report.traces for r in t.requests}
        assert done == set(range(6))
        assert fleet.generated == ref_gen, f"diverged (readable={readable})"
        recomputed[readable] = report.meta["recomputed_tokens"]
        assert fleet.fault_log[0]["kind"] == "kill"
        if readable:
            assert report.meta["recovered_page_copy"] >= 1.0
            assert report.meta["recovered_recompute"] == 0.0
        else:
            assert report.meta["recovered_page_copy"] == 0.0
            assert report.meta["recovered_recompute"] >= 1.0
            assert report.meta["time_to_recover_s"] > 0.0
        _assert_no_leaks(fleet)
    # the point of page-copy recovery: the hard kill re-pays generated
    # prefixes; the soft kill pays nothing
    assert recomputed[True] == 0.0
    assert recomputed[False] > 0.0


# --------------------------------------------------------------------------- #
# In-flight rebalancing: stealing RUNNING slots off a straggler              #
# --------------------------------------------------------------------------- #
def test_running_steal_improves_straggler_makespan(model_and_params):
    """One long request RUNNING on the slow replica, nothing queued: the
    queued-only thief has nothing to take, the running-slot thief migrates
    the decode mid-flight and strictly improves the fleet makespan — at
    exact token parity and zero recompute."""
    model, params = model_and_params
    specs = [ReplicaSpec(speed_factor=1.0), ReplicaSpec(speed_factor=0.25)]

    def requests():
        # odd rid (→ slow replica 1 under round-robin) is the straggler
        return [
            Request(rid=0, n_prefill=8, n_decode=4),
            Request(rid=1, n_prefill=10, n_decode=32),
            Request(rid=2, n_prefill=8, n_decode=4),
        ]

    results = {}
    for running in (True, False):
        fleet = _fleet(
            model, params, specs=specs,
            work_stealing=True, steal_running=running,
        )
        fleet.serve(requests(), LagrangianPolicy)      # warm
        report = fleet.serve(requests(), LagrangianPolicy)
        report.validate()
        assert all(eng.recomputed_tokens == 0 for eng in fleet.engines)
        _assert_no_leaks(fleet)
        results[running] = (report, dict(fleet.generated), fleet)
    on_report, on_gen, on_fleet = results[True]
    off_report, off_gen, _ = results[False]
    assert on_fleet.migration_events >= 1
    # the migrated slot moved fast-ward: slow donor (1) → fast thief (0)
    assert any(
        e.get("running") for e in on_fleet.steal_log
    ), "no running-slot steal recorded"
    assert on_gen == off_gen                           # placement-invariant
    assert on_report.makespan < off_report.makespan


# --------------------------------------------------------------------------- #
# Satellite: fleet checkpoints round-trip fault state                         #
# --------------------------------------------------------------------------- #
def test_fleet_checkpoint_round_trips_fault_state(model_and_params):
    model, params = model_and_params

    def requests():
        return [Request(rid=i, n_prefill=10, n_decode=10) for i in range(6)]

    fleet = _fleet(model, params)
    fleet.begin_serve(
        requests(), LagrangianPolicy,
        fault_plan=FaultPlan([ReplicaFault(replica=0, at_s=0.0)]),
    )
    steps = 0
    while not fleet.fault_log and fleet.step():
        steps += 1
    assert fleet.fault_log, "kill never applied"
    for _ in range(3):
        fleet.step()
    state = jax.tree_util.tree_map(np.asarray, fleet.state_dict())
    pre = {rid: list(t) for rid, t in fleet.generated.items()}
    lost = fleet._lost_preemptions

    fleet2 = _fleet(model, params)
    fleet2.load_state_dict(state, {r.rid: r for r in requests()})
    # the regression: a restored fleet used to forget who was dead — it
    # would dispatch to the killed replica and drop the fault accounting
    assert fleet2._dead == {0}
    assert fleet2.alive_replicas == [1]
    assert fleet2._lost_preemptions == lost
    assert fleet2.recovered_requests == fleet.recovered_requests
    assert fleet2.fault_log == fleet.fault_log
    while fleet2.step():
        pass
    report2 = fleet2.finish_serve()
    assert report2.meta["dead_replicas"] == 1.0
    assert report2.meta["fault_events"] == 1.0
    assert report2.meta["lost_preemptions"] == float(lost)
    post = fleet2.generated
    # pre-checkpoint + post-restore tokens cover every request exactly once
    uninterrupted = _fleet(model, params)
    full = uninterrupted.serve(
        requests(), LagrangianPolicy,
        fault_plan=FaultPlan([ReplicaFault(replica=0, at_s=0.0)]),
    )
    full.validate()
    for rid, toks in uninterrupted.generated.items():
        assert pre.get(rid, []) + post.get(rid, []) == toks, f"rid {rid}"


# --------------------------------------------------------------------------- #
# Satellite: debug_invariants wiring                                          #
# --------------------------------------------------------------------------- #
def _engine(model, params, **kw):
    eng = Engine(model, params, EngineConfig(**{**ENGINE_CFG, **kw}))
    eng.profiler.cost_model = CM
    return eng


def test_debug_invariants_resolution(model_and_params, monkeypatch):
    model, params = model_and_params
    # conftest exports REPRO_DEBUG_INVARIANTS=1 → on by default under pytest
    assert _engine(model, params).debug_invariants is True
    # explicit config wins over the environment, both ways
    assert _engine(model, params, debug_invariants=False).debug_invariants \
        is False
    monkeypatch.delenv("REPRO_DEBUG_INVARIANTS", raising=False)
    assert _engine(model, params).debug_invariants is False
    assert _engine(model, params, debug_invariants=True).debug_invariants \
        is True


def test_debug_invariants_catch_tampered_block_table(model_and_params):
    """The stage-boundary check actually trips: corrupting the device
    block-table mirror mid-serve fails the very next stage."""
    model, params = model_and_params
    from repro.core import GlobalQueueScheduler, build_clients

    eng = _engine(model, params)
    reqs = [Request(rid=0, n_prefill=10, n_decode=8)]
    clients = build_clients(eng.cfg.n_slots, reqs, None)
    eng.begin_serve(reqs, clients, GlobalQueueScheduler(reqs),
                    LagrangianPolicy())
    while not eng.slots.active_slots:
        eng.serve_step()
    slot = eng.slots.active_slots[0]
    eng.slots.cache["block_tables"] = (
        eng.slots.cache["block_tables"].at[slot, 0].add(1)
    )
    with pytest.raises(AssertionError, match="diverged from"):
        eng.serve_step()
