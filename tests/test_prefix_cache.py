"""Prefix caching with refcounted copy-on-write paged KV.

Covers the whole stack: BlockAllocator refcount semantics, the
content-addressed PrefixCacheIndex (chained page hashing, LRU leaf-first
eviction), COW adoption at every divergence point (chunk boundaries and
mid-page, greedy and seeded top-p), a 500-step random share/COW/evict churn
with refcount invariants at every step, migration of shared pages
(checksums preserved, never double-freed), cache-aware pricing through
iteration/offline/hetero, and the shared-prefix workload generator."""
import jax
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core import (
    BalancedLagrangianPolicy,
    CostModel,
    GlobalQueueScheduler,
    build_clients,
)
from repro.core.hetero import hetero_weights, replica_request_weight
from repro.core.iteration import CandidateBatch
from repro.core.offline import request_weights
from repro.core.types import Request
from repro.data import WorkloadSpec, shared_prefix_workload
from repro.models.layers import init_params
from repro.models.transformer import TransformerLM
from repro.serving.engine import Engine, EngineConfig
from repro.serving.kv_slots import (
    BlockAllocator,
    PageIntegrityError,
    PagedSlotManager,
    PrefixCacheIndex,
)
from repro.serving.sampler import TopPSampler

CFG = ArchConfig(
    name="demo", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=256,
)
CM = CostModel(level_caps=(32, 64, 128))


@pytest.fixture(scope="module")
def model_and_params():
    model = TransformerLM(CFG)
    params = init_params(jax.random.key(0), model.param_defs())
    return model, params


class _StubModel:
    """Just enough model for a PagedSlotManager: a tiny paged cache."""

    def paged_cache_init(self, num_pages, page_size, n_slots, mb):
        from repro.models.cache import paged_cache_init

        return paged_cache_init(1, num_pages, page_size, 1, 4, n_slots, mb)


def _mgr(n_slots=4, max_len=64, page_size=4, num_pages=32, prefix_cache=True):
    return PagedSlotManager(
        _StubModel(), n_slots, max_len, page_size, num_pages,
        prefix_cache=prefix_cache,
    )


def _check_all(mgr):
    mgr.allocator.check_consistency()
    mgr.check_block_table_mirror()
    mgr.check_refcounts()


# --------------------------------------------------------------------------- #
# Refcounted BlockAllocator                                                   #
# --------------------------------------------------------------------------- #
def test_allocator_share_release_refcounts():
    a = BlockAllocator(num_pages=8, page_size=16)
    pages = a.allocate(2)
    assert all(a.ref_count(p) == 1 for p in pages)
    a.share(pages)
    assert all(a.ref_count(p) == 2 for p in pages)
    assert a.num_shared() == 2
    assert a.release(pages) == []          # one owner left — nothing freed
    assert a.num_used == 2
    assert sorted(a.release(pages)) == sorted(pages)   # last owner
    assert a.num_used == 0
    with pytest.raises(RuntimeError, match="double free"):
        a.release(pages)
    with pytest.raises(RuntimeError, match="share of free"):
        a.share(pages)
    a.check_consistency()


def test_allocator_reset_multiplicity_is_refcount():
    a = BlockAllocator(num_pages=8, page_size=16)
    a.reset(in_use=[3, 3, 5])              # page 3 shared by two rows
    assert a.ref_count(3) == 2 and a.ref_count(5) == 1
    assert a.num_used == 2 and a.num_free == 6
    a.check_consistency()


# --------------------------------------------------------------------------- #
# PrefixCacheIndex: chained hashing, partial match, leaf-first eviction       #
# --------------------------------------------------------------------------- #
def test_index_full_and_partial_match():
    a = BlockAllocator(num_pages=16, page_size=4)
    idx = PrefixCacheIndex(a, page_size=4)
    toks = np.arange(1, 13, dtype=np.int32)            # 3 full pages
    pages = a.allocate(3)
    assert idx.insert(toks, pages) == 3
    assert idx.insert(toks, pages) == 0                # idempotent republish
    full, partial = idx.match(toks)
    assert full == pages and partial is None
    # diverge inside page 2 (tokens 8..11): first 2 pages full, page 3 is
    # the COW source with 2 matched tokens
    probe = toks.copy()
    probe[10:] = 99
    full, partial = idx.match(probe)
    assert full == pages[:2]
    assert partial == (pages[2], 2)
    # clean miss on the very first page — no full pages, partial inside it
    probe2 = toks.copy()
    probe2[0] = 77
    full, partial = idx.match(probe2)
    assert full == [] and partial is None


def test_index_eviction_is_leaf_first_and_refcount_gated():
    a = BlockAllocator(num_pages=16, page_size=4)
    idx = PrefixCacheIndex(a, page_size=4)
    toks = np.arange(1, 13, dtype=np.int32)
    pages = a.allocate(3)
    idx.insert(toks, pages)
    a.free(pages)                                      # index is sole owner
    # page 0 is the parent of a chain — reclaim(1) must take the leaf
    assert idx.reclaim(1) == 1
    assert len(idx) == 2
    full, _ = idx.match(toks)
    assert full == pages[:2]                           # prefix still serves
    # a page some slot still shares (ref 2) is not evictable
    a.reset()
    idx.invalidate()
    pages = a.allocate(2)
    idx.insert(toks[:8], pages)
    # simulate a slot adoption: pages gain an owner beyond the index
    a.share(pages)
    a.free(pages)                                      # publisher released
    assert idx.reclaimable_pages() == 0                # still co-owned
    assert idx.reclaim(10) == 0
    a.free(pages)                                      # adopter released
    assert idx.reclaimable_pages() == 2
    assert idx.reclaim(10) == 2
    assert a.num_used == 0


# --------------------------------------------------------------------------- #
# COW adoption: every divergence point (page boundary, chunk boundary,       #
# mid-page), via the manager's block-table arithmetic                         #
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("div", list(range(1, 16)))
def test_cow_divergence_matrix_manager(div):
    ps = 4
    mgr = _mgr(page_size=ps, num_pages=32)
    prompt = np.arange(1, 17, dtype=np.int32)          # 16 tokens, 4 pages
    mgr.reserve_with_prefix(0, prompt, len(prompt))
    mgr.bind(0, Request(rid=0, n_prefill=16, n_decode=2))
    assert mgr.publish_prefix(0, prompt) == 4
    other = prompt.copy()
    other[div:] = other[div:] + 100                    # diverge at ``div``
    before = mgr.cow_copies
    cached = mgr.reserve_with_prefix(1, other, len(other))
    assert cached == min(div, len(other) - 1)
    n_shared = cached // ps
    # fully matched pages are the publisher's very pages, shared read-only
    assert mgr.tables[1][:n_shared] == mgr.tables[0][:n_shared]
    for p in mgr.tables[1][:n_shared]:
        assert mgr.allocator.ref_count(p) >= 3         # slot0 + slot1 + index
    # everything from the divergence page on is private to the adopter
    assert not set(mgr.tables[1][n_shared:]) & set(mgr.tables[0])
    if cached % ps:
        assert mgr.cow_copies == before + 1            # divergence page copied
    _check_all(mgr)
    # release both slots; the index keeps the published pages alive
    mgr.release(0)
    mgr.free_pages_of(1)
    _check_all(mgr)
    assert mgr.allocator.num_used == 4                 # the index's holds
    assert mgr.prefix_index.clear() == 4
    assert mgr.allocator.num_used == 0


def test_adoption_clamps_to_recompute_last_token():
    # a full-prompt cache hit must still recompute ≥ 1 token: the final
    # token's logits seed the first output token
    mgr = _mgr(page_size=4, num_pages=32)
    prompt = np.arange(1, 17, dtype=np.int32)
    mgr.reserve_with_prefix(0, prompt, len(prompt))
    mgr.bind(0, Request(rid=0, n_prefill=16, n_decode=2))
    mgr.publish_prefix(0, prompt)
    cached = mgr.reserve_with_prefix(1, prompt, len(prompt))
    assert cached == len(prompt) - 1
    _check_all(mgr)


# --------------------------------------------------------------------------- #
# 500-step random share / COW / evict churn (satellite a)                     #
# --------------------------------------------------------------------------- #
def test_refcount_churn_500_steps():
    rng = np.random.default_rng(0)
    ps = 4
    mgr = _mgr(n_slots=6, max_len=32, page_size=ps, num_pages=48)
    heads = [
        rng.integers(1, 200, size=12).astype(np.int32) for _ in range(3)
    ]
    live: dict = {}
    for step in range(500):
        op = rng.random()
        free = [s for s in range(6) if s not in live]
        if op < 0.55 and free:
            slot = int(rng.choice(free))
            head = heads[int(rng.integers(0, 3))]
            tail = rng.integers(200, 250, size=int(rng.integers(1, 16)))
            prompt = np.concatenate([head, tail.astype(np.int32)])
            prompt = prompt[: mgr.max_len]
            try:
                mgr.reserve_with_prefix(slot, prompt, len(prompt))
            except RuntimeError:
                if live:                       # pool exhausted — evict someone
                    victim = int(rng.choice(list(live)))
                    mgr.free_pages_of(victim)
                    del live[victim]
                continue
            live[slot] = prompt
            if rng.random() < 0.7:             # most prompts complete+publish
                mgr.publish_prefix(slot, prompt)
        elif op < 0.75 and live:
            slot = int(rng.choice(list(live)))
            mgr.free_pages_of(slot)
            del live[slot]
        elif op < 0.85 and live:
            slot = int(rng.choice(list(live)))  # decode growth
            try:
                mgr.ensure_tokens(slot, min(len(live[slot]) + 8, mgr.max_len))
            except RuntimeError:
                pass
        else:
            mgr.prefix_index.reclaim(int(rng.integers(1, 5)))
        _check_all(mgr)                        # invariants EVERY step
    for slot in list(live):
        mgr.free_pages_of(slot)
    _check_all(mgr)
    held = len(mgr.prefix_index.held_pages())
    assert mgr.allocator.num_used == held      # only index holds remain
    assert mgr.prefix_index.clear() == held
    assert mgr.allocator.num_used == 0         # refcount-clean pool


# --------------------------------------------------------------------------- #
# Migration of shared pages (satellite b)                                     #
# --------------------------------------------------------------------------- #
def test_export_import_shared_pages_preserves_checksum():
    src = _mgr(page_size=4, num_pages=32)
    dst = _mgr(page_size=4, num_pages=32)
    prompt = np.arange(1, 17, dtype=np.int32)
    src.reserve_with_prefix(0, prompt, len(prompt))
    src.bind(0, Request(rid=0, n_prefill=16, n_decode=2))
    src.publish_prefix(0, prompt)
    cached = src.reserve_with_prefix(1, prompt, len(prompt))
    assert cached > 0                          # slot 1 SHARES slot 0's pages
    pages, k, v, length, crc = src.export_pages(1)
    dst.import_pages(0, k, v, length, checksum=crc)
    # the import landed on fresh private pages — shared-ness never crosses
    assert all(dst.allocator.ref_count(p) == 1 for p in dst.tables[0])
    # freeing the exporter's slot decrements, never double-frees: the
    # publisher and the index still co-own the shared prefix pages
    src.free_pages_of(1)
    _check_all(src)
    src.release(0)
    _check_all(src)
    assert src.allocator.num_used == len(src.prefix_index.held_pages())
    src.prefix_index.clear()
    assert src.allocator.num_used == 0
    _check_all(dst)


def test_import_bit_flip_rejected_pool_untouched():
    src = _mgr(page_size=4, num_pages=32)
    dst = _mgr(page_size=4, num_pages=32)
    prompt = np.arange(1, 17, dtype=np.int32)
    src.reserve_with_prefix(0, prompt, len(prompt))
    src.bind(0, Request(rid=0, n_prefill=16, n_decode=2))
    pages, k, v, length, crc = src.export_pages(0)
    k_bad = k.at[0, 0, 0, 0, 0].add(1.0)       # one flipped element
    used = dst.allocator.num_used
    with pytest.raises(PageIntegrityError):
        dst.import_pages(0, k_bad, v, length, checksum=crc)
    assert dst.allocator.num_used == used      # nothing allocated
    assert dst.tables[0] == []
    _check_all(dst)


def test_double_free_of_shared_page_raises():
    mgr = _mgr(page_size=4, num_pages=32)
    prompt = np.arange(1, 18, dtype=np.int32)  # 17 tokens: 5 pages, 4 full
    mgr.reserve_with_prefix(0, prompt, len(prompt))
    mgr.bind(0, Request(rid=0, n_prefill=17, n_decode=2))
    mgr.publish_prefix(0, prompt)              # partial last page NOT indexed
    pages = list(mgr.tables[0])
    mgr.release(0)                             # frees only the partial page
    # the naive "free the block table twice" bug: the slot's ids are stale —
    # its partial page is already on the free list, so a second release of
    # the row must raise instead of silently stripping the index's holds
    with pytest.raises(RuntimeError, match="double free"):
        mgr.allocator.release(pages)
    mgr.check_refcounts()                      # the raise left state intact
    mgr.prefix_index.clear()
    assert mgr.allocator.num_used == 0


# --------------------------------------------------------------------------- #
# Engine end-to-end: bit-identical streams at every divergence point,        #
# greedy and seeded top-p (satellite c)                                       #
# --------------------------------------------------------------------------- #
def _grouped_requests(prefix_lens, per_group=2, n_prefill=40, n_decode=5):
    # group members are a full pass apart in FCFS order, so a group's first
    # member publishes its prefix before its second member admits
    reqs = []
    rid = 0
    for _ in range(per_group):
        for g, plen in enumerate(prefix_lens):
            reqs.append(
                Request(
                    rid=rid, n_prefill=n_prefill, n_decode=n_decode,
                    prefix_group=g, prefix_len=plen,
                )
            )
            rid += 1
    return reqs


def _serve(model, params, reqs, prefix_cache, sampler=None, **cfg_kw):
    kw = dict(
        n_slots=4, max_len=128, kv_layout="paged", page_size=8,
        prefill_chunk=16, num_pages=128, prefix_cache=prefix_cache,
    )
    kw.update(cfg_kw)
    eng = Engine(
        model, params, EngineConfig(**kw),
        **({"sampler": sampler} if sampler is not None else {}),
    )
    eng.profiler.cost_model = CM
    trace = eng.serve(
        reqs, build_clients(kw["n_slots"], reqs),
        GlobalQueueScheduler(reqs), BalancedLagrangianPolicy(),
    )
    return eng, trace

def test_engine_parity_every_divergence_point(model_and_params):
    model, params = model_and_params
    # divergence at page boundaries (8, 24), chunk boundaries (16, 32),
    # mid-page (5, 13, 27), and a near-full-prompt prefix (39)
    prefix_lens = [5, 8, 13, 16, 24, 27, 32, 39]
    e0, t0 = _serve(model, params, _grouped_requests(prefix_lens), False)
    e1, t1 = _serve(model, params, _grouped_requests(prefix_lens), True)
    assert e0.generated == e1.generated        # bit-identical token streams
    assert e1.cache_hit_tokens > 0
    assert t1.computed_prefill_tokens < t0.computed_prefill_tokens
    # every prompt token is either computed or served from cache
    assert (
        t1.computed_prefill_tokens + e1.cache_hit_tokens
        == t0.computed_prefill_tokens
    )
    assert t1.meta["cached_prefill_tokens"] == e1.cache_hit_tokens
    assert t1.summary()["cached_prefill_tokens"] == e1.cache_hit_tokens
    assert t1.summary()["computed_prefill_tokens"] == t1.computed_prefill_tokens
    # pool ends refcount-clean: all remaining pages are index holds
    e1.slots.check_refcounts()
    held = len(e1.slots.prefix_index.held_pages())
    assert e1.slots.allocator.num_used == held
    assert e1.slots.prefix_index.clear() == held
    assert e1.slots.allocator.num_used == 0


def test_engine_parity_seeded_top_p(model_and_params):
    model, params = model_and_params
    reqs_fn = lambda: _grouped_requests([16, 27], per_group=3)  # noqa: E731
    e0, _ = _serve(
        model, params, reqs_fn(), False, sampler=TopPSampler(top_p=0.9)
    )
    e1, _ = _serve(
        model, params, reqs_fn(), True, sampler=TopPSampler(top_p=0.9)
    )
    assert e0.generated == e1.generated
    assert e1.cache_hit_tokens > 0


def test_dense_layout_unaffected(model_and_params):
    model, params = model_and_params
    reqs = _grouped_requests([16], per_group=2, n_prefill=24, n_decode=4)
    eng = Engine(
        model, params,
        EngineConfig(n_slots=4, max_len=64, kv_layout="dense"),
    )
    eng.profiler.cost_model = CM
    trace = eng.serve(
        reqs, build_clients(4, reqs), GlobalQueueScheduler(reqs),
        BalancedLagrangianPolicy(),
    )
    trace.validate()
    assert eng.cache_hit_tokens == 0
    assert trace.meta["cached_prefill_tokens"] == 0
    # dense prompts share the same group-derived tokens, so a paged
    # cache-on serve of the same workload emits the same streams
    e1, _ = _serve(
        model, params,
        _grouped_requests([16], per_group=2, n_prefill=24, n_decode=4),
        True, max_len=64,
    )
    assert eng.generated == e1.generated


def test_prefix_cache_requires_paged_layout(model_and_params):
    model, params = model_and_params
    with pytest.raises(ValueError, match="prefix_cache"):
        Engine(
            model, params,
            EngineConfig(kv_layout="dense", prefix_cache=True),
        )


# --------------------------------------------------------------------------- #
# Cache-aware pricing (iteration / offline / hetero)                          #
# --------------------------------------------------------------------------- #
def test_request_uncached_prefill_accounting():
    r = Request(rid=0, n_prefill=100, n_decode=10, prefix_group=1, prefix_len=40)
    assert r.uncached_prefill == 100
    r.cached_prefill = 60
    assert r.uncached_prefill == 40
    r.reset()
    assert r.cached_prefill == 0               # execution state clears
    assert r.prefix_group == 1 and r.prefix_len == 40   # identity survives
    with pytest.raises(ValueError):
        Request(rid=1, n_prefill=10, n_decode=1, prefix_len=11)


def test_candidate_batch_uncached_tokens():
    reqs = [Request(rid=i, n_prefill=50, n_decode=5) for i in range(2)]
    cb = CandidateBatch(requests=reqs, client_ids=[0, 1], cached_tokens=60)
    assert cb.total_prefill_tokens == 100
    assert cb.uncached_prefill_tokens == 40
    cb_over = CandidateBatch(requests=reqs, client_ids=[0, 1], cached_tokens=999)
    assert cb_over.uncached_prefill_tokens == 0


def test_offline_weights_cache_aware_vs_blind():
    reqs = [Request(rid=0, n_prefill=200, n_decode=10)]
    reqs[0].cached_prefill = 150
    aware = request_weights(reqs, CM, 1, include_prefill=True, cache_aware=True)
    blind = request_weights(reqs, CM, 1, include_prefill=True, cache_aware=False)
    assert aware[0] < blind[0]
    assert blind[0] - aware[0] == pytest.approx(
        CM.prefill_time(200) - CM.prefill_time(50)
    )


def test_hetero_weights_take_cached_matrix():
    reqs = [Request(rid=0, n_prefill=100, n_decode=10, n_decode_est=10)]
    cold = replica_request_weight(reqs[0], CM, 4)
    warm = replica_request_weight(reqs[0], CM, 4, cached_prefill=80)
    assert warm < cold
    w_cold = hetero_weights(reqs, [CM, CM], 4)
    w_warm = hetero_weights(
        reqs, [CM, CM], 4, cached_tokens=np.array([[80, 0]])
    )
    assert w_warm[0, 0] < w_cold[0, 0]         # replica 0 is warm
    assert w_warm[0, 1] == pytest.approx(w_cold[0, 1])
    with pytest.raises(ValueError):
        hetero_weights(reqs, [CM, CM], 4, cached_tokens=np.zeros((2, 2)))


# --------------------------------------------------------------------------- #
# Shared-prefix workload generator                                            #
# --------------------------------------------------------------------------- #
def test_shared_prefix_workload_shape():
    spec = WorkloadSpec(n_requests=200, input_mean=60, input_std=20)
    reqs = sorted(
        shared_prefix_workload(spec, seed=3, n_groups=4),
        key=lambda r: r.rid,
    )
    assert len(reqs) == 200
    groups = {}
    for r in reqs:
        assert r.prefix_group is not None and 0 <= r.prefix_group < 4
        assert 0 < r.prefix_len < r.n_prefill
        groups.setdefault(r.prefix_group, []).append(r.prefix_len)
    # one prefix length per group, Zipf skew makes group 0 the hottest
    for plens in groups.values():
        assert len(set(plens)) == 1
    counts = {g: len(v) for g, v in groups.items()}
    assert counts[0] == max(counts.values())
