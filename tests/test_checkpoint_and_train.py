"""Checkpoint atomicity/restore, train-loop resume, optimizer behaviour,
gradient compression numerics."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointManager,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update


def _tree():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((2, 2), jnp.bfloat16) * 1.5},
        "c": jnp.asarray(7, jnp.int32),
    }


def test_checkpoint_roundtrip_bf16_exact(tmp_path):
    tree = _tree()
    save_checkpoint(tmp_path, 5, tree, metadata={"note": "x"})
    restored, meta = restore_checkpoint(tmp_path, target=tree)
    assert meta["note"] == "x"
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_checkpoint_atomicity_ignores_incomplete(tmp_path):
    tree = _tree()
    save_checkpoint(tmp_path, 1, tree)
    # fake a crashed step-2: directory without COMPLETE marker
    (tmp_path / "step_00000002").mkdir()
    (tmp_path / "step_00000002" / "arrays.npz").write_bytes(b"garbage")
    assert latest_step(tmp_path) == 1
    restored, _ = restore_checkpoint(tmp_path, target=tree)
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))


def test_checkpoint_prune_keeps_latest(tmp_path):
    tree = {"x": jnp.zeros(3)}
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(tmp_path, s, tree, keep=2)
    from repro.checkpoint.checkpoint import latest_steps

    assert latest_steps(tmp_path) == [4, 5]


def test_train_loop_resumes(tmp_path):
    from repro.configs import get_smoke_config
    from repro.train.train_loop import TrainConfig, train

    cfg = get_smoke_config("granite_3_8b")
    tc = TrainConfig(steps=6, batch=2, seq=16, checkpoint_dir=str(tmp_path),
                     save_every=2, log_every=0)
    out1 = train(cfg, tc)
    assert out1["steps_run"] == 6
    tc2 = TrainConfig(steps=9, batch=2, seq=16, checkpoint_dir=str(tmp_path),
                      save_every=2, log_every=0)
    out2 = train(cfg, tc2)
    assert out2["start_step"] == 6
    assert out2["steps_run"] == 3


def test_train_loss_decreases():
    from repro.configs import get_smoke_config
    from repro.train.train_loop import TrainConfig, train

    cfg = get_smoke_config("qwen3_8b")
    out = train(cfg, TrainConfig(steps=60, batch=4, seq=32, log_every=0),
                AdamWConfig(lr=5e-3, warmup_steps=5))
    assert out["last_loss"] < out["first_loss"] - 0.3


def test_adamw_moves_toward_minimum():
    params = {"w": jnp.asarray([4.0, -2.0])}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}  # d/dw ||w||²
        params, opt, _ = adamw_update(grads, opt, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_grad_clip_bounds_update():
    params = {"w": jnp.zeros(4)}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=1.0, grad_clip_norm=1.0, weight_decay=0.0, warmup_steps=1)
    huge = {"w": jnp.full(4, 1e9)}
    params2, _, metrics = adamw_update(huge, opt, params, cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(2e9, rel=1e-5)
    assert float(jnp.abs(params2["w"]).max()) <= 1.001  # lr * normalized step


def test_int8_compression_numerics():
    """compressed psum ≈ exact psum; error feedback drives long-run bias → 0."""
    from repro.distributed.collectives import dequantize_int8, quantize_int8

    x = jax.random.normal(jax.random.key(0), (512,)) * 3.0
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    assert err.max() <= float(s) * 0.5 + 1e-6  # half-ULP bound

    # error feedback: accumulated compressed sum tracks the true sum
    true_acc = np.zeros(64)
    comp_acc = np.zeros(64)
    e = jnp.zeros(64)
    rng = np.random.default_rng(0)
    for step in range(50):
        g = jnp.asarray(rng.normal(size=64) * 0.1)
        gf = g + e
        q, s = quantize_int8(gf)
        deq = dequantize_int8(q, s)
        e = gf - deq
        true_acc += np.asarray(g)
        comp_acc += np.asarray(deq)
    # residual bounded by one quantization step, not growing with steps
    assert np.abs(true_acc - comp_acc).max() < 0.05


@pytest.mark.slow
def test_microbatch_accumulation_matches_single_batch():
    """bf16-accumulated grad-accum step ≈ single-batch step."""
    from repro.configs import get_smoke_config
    from repro.models.layers import init_params
    from repro.models.registry import get_model
    from repro.train.train_step import make_train_step

    cfg = get_smoke_config("granite_3_8b")
    model = get_model(cfg)
    params = init_params(jax.random.key(0), model.param_defs())
    opt = adamw_init(params)
    rng = np.random.default_rng(0)
    tokens = rng.integers(0, cfg.vocab_size, size=(4, 16)).astype(np.int32)
    batch = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(np.roll(tokens, -1, 1))}
    s1 = make_train_step(model, AdamWConfig(warmup_steps=1), microbatches=1)
    s4 = make_train_step(model, AdamWConfig(warmup_steps=1), microbatches=4,
                         accum_dtype=jnp.float32)
    p1, _, m1 = jax.jit(s1)(params, opt, batch)
    p4, _, m4 = jax.jit(s4)(params, adamw_init(params), batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=2e-2)
    l1 = jax.tree_util.tree_leaves(p1)[3]
    l4 = jax.tree_util.tree_leaves(p4)[3]
    np.testing.assert_allclose(
        np.asarray(l1, np.float32), np.asarray(l4, np.float32), rtol=0.1, atol=5e-3
    )
