"""Mixed-step (continuous batching) vs alternating-stage scheduling: exact
token parity (greedy and seeded top-p), stall elimination, mid-round slot
finishes, a chunk completing in the same round a decode row hits EOS,
checkpoint/restore between mixed rounds with a mid-chunk cursor, the
pure-decode fused fast path, prefill_share pricing, the separable mixed-batch
cost-model fit, and the arrival-gated scheduler."""
import jax
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core import (
    ArrivalQueueScheduler,
    CostModel,
    DecodeFirstPolicy,
    GlobalQueueScheduler,
    LagrangianPolicy,
    PrefillFirstPolicy,
    build_clients,
)
from repro.core.iteration import CandidateBatch, SystemSnapshot
from repro.core.types import Request, StageKind
from repro.data import WorkloadSpec, gsm8k_like_workload
from repro.models.layers import init_params
from repro.models.transformer import TransformerLM
from repro.serving.engine import Engine, EngineConfig
from repro.serving.profiler import OnlineProfiler
from repro.serving.sampler import TopPSampler, greedy

CFG = ArchConfig(
    name="demo", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=256,
)
# multi-chunk prompts next to decode-heavy outputs: chunk rounds and decode
# rounds genuinely compete, so mixed vs alternating schedules diverge
SPEC = WorkloadSpec(
    n_requests=10, input_mean=30, input_std=20, output_mean=10,
    output_std=6, output_max=16, input_max=60,
)
CM = CostModel(level_caps=(32, 64, 128))


@pytest.fixture(scope="module")
def model_and_params():
    model = TransformerLM(CFG)
    params = init_params(jax.random.key(0), model.param_defs())
    return model, params


def _engine(model, params, mixed=True, sampler=greedy, **kw):
    kw.setdefault("page_size", 16)
    kw.setdefault("prefill_chunk", 16)
    kw.setdefault("num_pages", 24)
    eng = Engine(
        model, params,
        EngineConfig(
            n_slots=4, max_len=80, prefill_seq_buckets=(32, 64),
            kv_layout="paged", mixed_schedule=mixed, **kw,
        ),
        sampler=sampler,
    )
    eng.profiler.cost_model = CM
    return eng


def _serve(eng, seed=5, policy=None, reqs=None):
    reqs = reqs or gsm8k_like_workload(SPEC, seed=seed, known_lengths=True)
    clients = build_clients(4, reqs, None)
    tr = eng.serve(
        reqs, clients, GlobalQueueScheduler(reqs), policy or PrefillFirstPolicy()
    )
    tr.validate()
    return tr


# --------------------------------------------------------------------------- #
# Token parity: mixed-step == alternating-stage                               #
# --------------------------------------------------------------------------- #
@pytest.mark.slow
def test_mixed_matches_alternating_greedy(model_and_params):
    model, params = model_and_params
    alt = _engine(model, params, mixed=False)
    tr_a = _serve(alt)
    mix = _engine(model, params, mixed=True)
    tr_m = _serve(mix)
    assert alt.generated.keys() == mix.generated.keys()
    for rid in alt.generated:
        assert alt.generated[rid] == mix.generated[rid], f"rid {rid}"
    # the point of the subsystem: the alternating engine froze decoders
    # behind chunk rounds; the mixed engine never did
    assert alt.prefill_stall_time > 0.0
    assert mix.prefill_stall_time == 0.0
    assert mix.mixed_rounds > 0 and alt.mixed_rounds == 0
    assert StageKind.MIXED in {s.kind for s in tr_m.stages}
    assert StageKind.MIXED not in {s.kind for s in tr_a.stages}
    # prefill stages may still appear in mixed mode, but only when nothing
    # was decoding (stall == 0 above proves no decoder froze behind one)
    # serve() results surface the counters without a benchmark run
    s = tr_m.summary()
    assert s["mixed_rounds"] == mix.mixed_rounds
    assert s["prefill_stall_time_s"] == 0.0
    assert tr_a.summary()["prefill_stall_time_s"] > 0.0


@pytest.mark.slow
def test_mixed_matches_alternating_seeded_top_p(model_and_params):
    model, params = model_and_params
    samp = TopPSampler(top_p=0.95)
    runs = {}
    for mixed in (False, True):
        eng = _engine(model, params, mixed=mixed, sampler=samp, sample_seed=3)
        _serve(eng)
        runs[mixed] = eng.generated
    assert runs[False].keys() == runs[True].keys()
    for rid in runs[False]:
        assert runs[False][rid] == runs[True][rid], f"rid {rid}"


@pytest.mark.slow
def test_mixed_lagrangian_share_serves_valid_trace(model_and_params):
    """The priced prefill_share must drive a complete, valid serve — and a
    slot must finish decoding inside some mixed round (release mid-round)."""
    model, params = model_and_params
    eng = _engine(model, params, mixed=True)
    tr = _serve(eng, seed=6, policy=LagrangianPolicy())
    assert eng.mixed_rounds > 0
    assert eng.prefill_stall_time == 0.0
    # at least one mixed stage carried decode lanes alongside chunk tokens
    assert any(
        s.kind is StageKind.MIXED and s.chunk_tokens and s.tokens > s.chunk_tokens
        for s in tr.stages
    )


# --------------------------------------------------------------------------- #
# Mid-round events: EOS and chunk completion in the same dispatch             #
# --------------------------------------------------------------------------- #
def test_chunk_completes_in_round_a_decode_row_hits_eos(model_and_params):
    """One mixed round in which slot A's decode row samples EOS while slot
    B's final prompt chunk lands: A must release exactly there with the
    truncated reference stream, B must bind with its reference first token."""
    model, params = model_and_params

    # reference streams from separate per-request serves (no EOS handling)
    ref = _engine(model, params, mixed=True)
    req_a = Request(rid=0, n_prefill=8, n_decode=12)
    _serve(ref, reqs=[req_a])
    stream_a = ref.generated[0]
    ref_b = _engine(model, params, mixed=True)
    req_b = Request(rid=1, n_prefill=40, n_decode=4)
    _serve(ref_b, reqs=[req_b])
    stream_b = ref_b.generated[1]

    # B needs 3 chunks of 16; its final chunk lands in the round that
    # decodes A's token at stream index 3 — make that token the EOS
    eos = stream_a[3]
    cut = stream_a.index(eos)
    assert cut <= 3, "EOS must not fire before the co-occurrence round"

    eng = _engine(model, params, mixed=True, eos_id=int(eos))
    a = Request(rid=0, n_prefill=8, n_decode=12)
    b = Request(rid=1, n_prefill=40, n_decode=4)
    clients = build_clients(4, [a, b], None)
    # round 0: A's single chunk (chunk-only mixed round; A binds)
    eng._start_chunked_batch([(clients[0], a)], 0, 0.0)
    plan, _ = eng._plan_mixed_round([], 8)
    _, _, _, _, fin, _, _ = eng._run_mixed_stage(plan)
    assert fin == [0]
    # rounds 1..3: A decodes one token per round while B chunks 16+16+8
    eng._start_chunked_batch([(clients[1], b)], 1, 0.0)
    for expect_idx, expect_chunk in ((1, 16), (2, 16), (3, 8)):
        plan, _ = eng._plan_mixed_round([], 16)
        dt, fin_dec, dec_tok, chunk_tok, fin_chunks, busy, busy_partial = (
            eng._run_mixed_stage(plan)
        )
        assert dec_tok == 1 and chunk_tok == expect_chunk
        if expect_idx < 3:
            assert not fin_dec and not fin_chunks
            assert busy_partial == {1: 1}
        else:
            # the co-occurrence round: EOS and final chunk in ONE dispatch
            assert fin_dec == [0] and fin_chunks == [1]
            assert busy == {0: 0, 1: 1}
    assert eng.generated[0] == stream_a[: cut + 1]
    assert eng.generated[1] == stream_b[:1]
    # continuing B from its fresh pending token reproduces the reference
    eng.slots.release(0)
    plan, _ = eng._plan_mixed_round([], 16)
    assert plan == []
    _, fin2, toks = eng._run_decode_stage(3)
    assert eng.generated[1] == stream_b[:4]


# --------------------------------------------------------------------------- #
# Checkpoint/restore between mixed rounds, mid-chunk cursor                   #
# --------------------------------------------------------------------------- #
def test_checkpoint_restore_between_mixed_rounds_mid_chunk(model_and_params):
    model, params = model_and_params

    def fresh():
        return _engine(model, params, mixed=True)

    a = Request(rid=0, n_prefill=8, n_decode=12)
    b = Request(rid=1, n_prefill=40, n_decode=6)
    eng = fresh()
    clients = build_clients(4, [a, b], None)
    eng._start_chunked_batch([(clients[0], a)], 0, 0.0)
    plan, _ = eng._plan_mixed_round([], 8)
    eng._run_mixed_stage(plan)                     # A bound
    eng._start_chunked_batch([(clients[1], b)], 1, 0.0)
    plan, _ = eng._plan_mixed_round([], 16)
    eng._run_mixed_stage(plan)                     # A +1 token, B cursor = 16
    assert eng._chunking[1].done == 16

    state = eng.state_dict()
    eng2 = fresh()
    eng2.load_state_dict(
        jax.tree_util.tree_map(np.asarray, state), {0: a, 1: b}
    )
    assert eng2._chunking[1].done == 16
    assert eng2.slots.emitted[0] == 2

    # both engines continue with identical plans → identical tokens + caches
    for e in (eng, eng2):
        for _ in range(2):
            plan, _ = e._plan_mixed_round([], 16)
            e._run_mixed_stage(plan)
    assert eng2.generated[0] == eng.generated[0][2:]   # post-restore suffix
    assert eng2.generated[1] == eng.generated[1]       # B sampled after save
    assert eng._chunking == {} and eng2._chunking == {}
    for x, y in zip(
        jax.tree_util.tree_leaves(eng.slots.cache),
        jax.tree_util.tree_leaves(eng2.slots.cache),
    ):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# --------------------------------------------------------------------------- #
# Pure-decode workloads keep the fused fast path                              #
# --------------------------------------------------------------------------- #
def test_pure_decode_fast_path_unchanged(model_and_params):
    """With no arrivals mid-decode (every prompt admitted in the opening
    chunk round, which runs as a plain prefill stage since nothing is
    decoding yet), the mixed engine must produce exactly the stage sequence
    the alternating engine does — same dispatch counts, no mixed rounds."""
    model, params = model_and_params

    def reqs():
        return [
            Request(rid=i, n_prefill=12, n_decode=d)
            for i, d in enumerate((10, 13, 7, 9))
        ]

    alt = _engine(model, params, mixed=False)
    tr_a = _serve(alt, reqs=reqs())
    mix = _engine(model, params, mixed=True)
    tr_m = _serve(mix, reqs=reqs())
    for rid in alt.generated:
        assert alt.generated[rid] == mix.generated[rid]
    assert mix.decode_dispatches == alt.decode_dispatches
    assert mix.decoded_tokens == alt.decoded_tokens
    assert mix.mixed_rounds == 0
    assert [(s.kind, s.rounds) for s in tr_m.stages] == [
        (s.kind, s.rounds) for s in tr_a.stages
    ]


# --------------------------------------------------------------------------- #
# prefill_share pricing                                                       #
# --------------------------------------------------------------------------- #
def _snap(pending, n_active=4, n_clients=4, n_cand=0, cand_prefill=32):
    cand = [
        Request(rid=i, n_prefill=cand_prefill, n_decode=4)
        for i in range(n_cand)
    ]
    return SystemSnapshot(
        n_clients=n_clients, n_active=n_active, n_idle=n_clients - n_active,
        active_remaining_est=64, pending_requests=pending,
        candidate=CandidateBatch(requests=cand, client_ids=list(range(n_cand))),
        now=0.0,
    )


def test_prefill_share_pricing():
    pol = LagrangianPolicy()
    cm = CostModel(level_caps=(64,))
    # no budget / no waiters → nothing to co-schedule
    assert pol.prefill_share(_snap(pending=4, n_cand=2), cm, 0) == 0
    assert pol.prefill_share(_snap(pending=0), cm, 64) == 0
    # nothing decoding → no latency to protect → the whole budget
    assert pol.prefill_share(_snap(pending=4, n_active=0, n_cand=2), cm, 64) == 64
    # the knob is continuous: share grows with outstanding prompt work...
    lo = pol.prefill_share(_snap(pending=1, n_cand=1, cand_prefill=8), cm, 10_000)
    hi = pol.prefill_share(_snap(pending=4, n_cand=4, cand_prefill=64), cm, 10_000)
    assert 0 < lo < hi
    # ...and shrinks as the per-prefill-token inflation grows
    cm_costly = CostModel(mixed_prefill_per_token=50e-3, level_caps=(64,))
    assert pol.prefill_share(
        _snap(pending=4, n_cand=4, cand_prefill=64), cm_costly, 10_000
    ) < hi
    # heavy inflation with a trickle of work collapses to pure decode
    assert pol.prefill_share(
        _snap(pending=1, n_cand=1, cand_prefill=1), cm_costly, 64
    ) == 0
    # baselines keep their stage-choice semantics
    assert PrefillFirstPolicy().prefill_share(_snap(pending=4, n_cand=2), cm, 48) == 48
    assert DecodeFirstPolicy().prefill_share(_snap(pending=4, n_cand=2), cm, 48) == 0
    assert DecodeFirstPolicy().prefill_share(
        _snap(pending=4, n_active=0, n_cand=2), cm, 48
    ) == 48


def test_decide_mixed_budget_returns_split():
    pol = LagrangianPolicy()
    cm = CostModel(level_caps=(64,))
    d = pol.decide(_snap(pending=4, n_cand=2), cm, k_max=8, mixed_budget=32)
    assert d.chunk_tokens > 0 and d.horizon == 1 and not d.prefill
    # share 0 → pure fused decode at the priced horizon
    d0 = pol.decide(_snap(pending=0), cm, k_max=8, mixed_budget=0)
    assert d0.chunk_tokens == 0 and d0.horizon == 8
    # binary mode untouched
    d_bin = pol.decide(_snap(pending=0), cm, k_max=8)
    assert d_bin.chunk_tokens == 0


# --------------------------------------------------------------------------- #
# Mixed-batch cost model: separable fit + online profiler                     #
# --------------------------------------------------------------------------- #
def test_mixed_round_time_defaults_derive_from_stage_model():
    cm = CostModel()
    assert cm.mixed_round_time(0, 0) == 0.0
    expect = cm.decode_overhead + 4 * cm.decode_per_token + 32 * cm.prefill_per_token
    assert cm.mixed_round_time(4, 32) == pytest.approx(expect)


def test_cost_model_mixed_fit_recovers_constants():
    true = CostModel(
        prefill_per_token=2e-3, prefill_overhead=5e-3,
        decode_per_token=1e-3, decode_overhead=4e-3,
        mixed_overhead=3e-3, mixed_decode_per_row=0.8e-3,
        mixed_prefill_per_token=0.4e-3, level_caps=(64, 128),
    )
    prefill = [(n, true.prefill_time(n)) for n in (16, 32, 64)]
    decode = [(n, true.decode_round_time(n)) for n in (2, 4, 8)]
    mixed = [
        (nd, npf, true.mixed_round_time(nd, npf))
        for nd in (0, 2, 4, 8) for npf in (0, 16, 32, 64)
        if nd or npf    # (0, 0) is a no-op round, not a model sample
    ]
    fit = CostModel.fit(prefill, decode, level_caps=(64, 128), mixed_samples=mixed)
    assert fit.mixed_overhead == pytest.approx(3e-3, rel=1e-6)
    assert fit.mixed_decode_per_row == pytest.approx(0.8e-3, rel=1e-6)
    assert fit.mixed_prefill_per_token == pytest.approx(0.4e-3, rel=1e-6)
    # degenerate mixed samples (no variation in n_p) → constants stay
    # derived from the stage-level model, not silently wrong
    fit2 = CostModel.fit(
        prefill, decode, level_caps=(64, 128),
        mixed_samples=[(n, 16, true.mixed_round_time(n, 16)) for n in (2, 4, 8)],
    )
    assert fit2.mixed_overhead is None
    assert fit2.mixed_prefill_token_time == fit2.prefill_per_token


def test_profiler_learns_mixed_model():
    prof = OnlineProfiler(initial=CostModel(level_caps=(64, 128)), refit_every=4)
    true = CostModel(
        prefill_per_token=2e-3, prefill_overhead=5e-3,
        decode_per_token=1e-3, decode_overhead=4e-3,
        mixed_overhead=6e-3, mixed_decode_per_row=1.5e-3,
        mixed_prefill_per_token=0.7e-3, level_caps=(64, 128),
    )
    for nd, npf in ((2, 0), (4, 16), (8, 32), (2, 64), (8, 0), (4, 48)):
        prof.record_prefill(16 + npf, true.prefill_time(16 + npf))
        prof.record_decode(max(nd, 1), true.decode_round_time(max(nd, 1)))
        prof.record_mixed(nd, npf, true.mixed_round_time(nd, npf))
    assert prof.fits >= 1
    assert prof.cost_model.mixed_prefill_per_token == pytest.approx(
        0.7e-3, rel=1e-3
    )
    assert prof.cost_model.mixed_decode_per_row == pytest.approx(1.5e-3, rel=1e-3)


def test_profiler_refits_mixed_constants_without_stage_variation():
    """A steady mixed-schedule serve can feed almost every sample through
    record_mixed — with no prefill/decode stage variation the full refit
    gate never opens, but the mixed constants must still adapt (regression:
    the share pricing silently never engaged)."""
    prof = OnlineProfiler(initial=CostModel(level_caps=(64,)), refit_every=4)
    true = CostModel(
        mixed_overhead=6e-3, mixed_decode_per_row=1.5e-3,
        mixed_prefill_per_token=0.7e-3,
    )
    for nd, npf in ((2, 16), (4, 32), (8, 0), (2, 48), (6, 8)):
        prof.record_mixed(nd, npf, true.mixed_round_time(nd, npf))
    assert prof.fits >= 1
    assert prof.cost_model.mixed_prefill_per_token == pytest.approx(
        0.7e-3, rel=1e-3
    )
    # the stage-level model stays at its prior — only the mixed constants
    # were identifiable
    assert prof.cost_model.decode_overhead == CostModel().decode_overhead


# --------------------------------------------------------------------------- #
# Arrival-gated scheduling (open-loop workloads)                              #
# --------------------------------------------------------------------------- #
def test_arrival_queue_scheduler_gates_on_clock():
    reqs = [
        Request(rid=i, n_prefill=4, n_decode=2, arrival=float(i))
        for i in range(3)
    ]
    sched = ArrivalQueueScheduler(reqs)
    client = build_clients(1, reqs, None)[0]
    # has_pending counts everything (serve-loop termination); pending_count
    # only *arrived* requests (the waiter pressure policies price against)
    assert sched.has_pending() and sched.pending_count() == 1
    assert sched.peek(client, set()).rid == 0
    assert sched.peek(client, {0}) is None          # rid 1 not arrived yet
    assert sched.next_arrival() == 1.0
    sched.set_now(1.5)
    assert sched.pending_count() == 2
    assert sched.peek(client, {0}).rid == 1
    assert sched.next_arrival() == 2.0
    sched.set_now(0.5)                               # the clock never rewinds
    assert sched.peek(client, {0}).rid == 1
    sched.commit(client, reqs[0])
    assert sched.pending_count() == 1
    assert sched.has_pending()


def test_engine_serves_poisson_arrivals(model_and_params):
    """Requests arriving mid-serve must be admitted when their time comes
    (idle gaps fast-forward instead of deadlocking) and produce the same
    token streams as a closed-loop serve of the same requests."""
    model, params = model_and_params
    closed = _engine(model, params, mixed=True)
    base_reqs = [
        Request(rid=i, n_prefill=10 + 3 * i, n_decode=6 + i) for i in range(5)
    ]
    _serve(closed, reqs=[Request(r.rid, r.n_prefill, r.n_decode) for r in base_reqs])

    eng = _engine(model, params, mixed=True)
    reqs = [Request(r.rid, r.n_prefill, r.n_decode) for r in base_reqs]
    # rid 0 at t=0; the rest arrive in two bursts, the last far in the
    # future so the engine must idle-wait for it after draining
    for r, arr in zip(reqs, (0.0, 0.005, 0.005, 0.01, 1e9)):
        r.arrival = arr
    clients = build_clients(4, reqs, None)
    tr = eng.serve(
        reqs, clients, ArrivalQueueScheduler(reqs), LagrangianPolicy()
    )
    tr.validate()
    assert eng.generated.keys() == closed.generated.keys()
    for rid in closed.generated:
        assert eng.generated[rid] == closed.generated[rid]
    assert reqs[-1].t_prefill_start is None or reqs[-1].t_done >= 1e9
