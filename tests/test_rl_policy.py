"""The RL iteration scheduler (paper future-work #2) trains and clears an
untrained baseline; see EXPERIMENTS.md for its standing vs analytic rules."""
import dataclasses

import numpy as np

from repro.core import PAPER_COST_MODEL, simulate
from repro.core.rl_policy import RLPolicy, train_rl_policy
from repro.data import (
    PAPER_PREDICTOR_NOISE_STD,
    PAPER_WORKLOAD_SPEC,
    gsm8k_like_workload,
)


def test_rl_policy_trains_and_beats_untrained():
    spec = dataclasses.replace(PAPER_WORKLOAD_SPEC, n_requests=200)

    def mk(ep):
        return gsm8k_like_workload(
            spec, seed=2000 + ep, estimate_noise_std=PAPER_PREDICTOR_NOISE_STD
        )

    trained = train_rl_policy(mk, 50, PAPER_COST_MODEL, episodes=12)
    assert np.abs(trained.q).sum() > 0  # actually learned something

    reqs = gsm8k_like_workload(spec, seed=0,
                               estimate_noise_std=PAPER_PREDICTOR_NOISE_STD)
    tr_trained = simulate(reqs, 50, PAPER_COST_MODEL, mode="hybrid",
                          iteration_policy=trained)
    # untrained Q-table = argmax over zeros = always decode-leaning
    tr_zero = simulate(reqs, 50, PAPER_COST_MODEL, mode="hybrid",
                       iteration_policy=RLPolicy())
    assert tr_trained.utilization >= tr_zero.utilization - 0.02
    assert tr_trained.utilization > 0.5
