"""Core scheduler unit + property tests: cost model, offline bin packing,
Algorithm 1, Lagrangian policy."""
import itertools

import numpy as np
import pytest
hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis"
)
from hypothesis import given, settings, strategies as st

from repro.core import (
    CostModel,
    PAPER_COST_MODEL,
    LagrangianPolicy,
    PrefillFirstPolicy,
    CandidateBatch,
    SystemSnapshot,
    build_clients,
    lpt_assign,
    local_search,
    make_requests,
    milp_assign,
    round_robin_assign,
    solve_offline,
    theoretical_lower_bound,
)
from repro.core.online import SortingPreemptiveScheduler, StaticBacklogScheduler


# --------------------------------------------------------------------------- #
# Cost model                                                                   #
# --------------------------------------------------------------------------- #
def test_paper_cost_model_constants():
    cm = PAPER_COST_MODEL
    # paper §V-A: 200-client decode round = 71 ms; 5000-token prefill = 675 ms
    assert cm.decode_round_time(200) == pytest.approx(0.071, abs=1e-9)
    assert cm.prefill_time(5000) == pytest.approx(0.675, abs=1e-9)


def test_levels_monotone_and_quantization():
    cm = PAPER_COST_MODEL
    caps = [l.cap_tokens for l in cm.levels]
    durs = [l.duration_s for l in cm.levels]
    assert caps == sorted(caps) and durs == sorted(durs)
    assert cm.level_for(1).cap_tokens == caps[0]
    assert cm.level_for(caps[-1]).cap_tokens == caps[-1]
    with pytest.raises(ValueError):
        cm.level_for(caps[-1] + 1)


def test_cost_model_fit_recovers_linear_params():
    cm = CostModel()
    pre = [(n, cm.prefill_time(n)) for n in (100, 500, 1000, 4000)]
    dec = [(n, cm.decode_round_time(n)) for n in (1, 50, 100, 200)]
    fit = CostModel.fit(pre, dec)
    assert fit.prefill_per_token == pytest.approx(cm.prefill_per_token, rel=1e-6)
    assert fit.decode_overhead == pytest.approx(cm.decode_overhead, rel=1e-6)


# --------------------------------------------------------------------------- #
# Offline bin packing                                                          #
# --------------------------------------------------------------------------- #
@given(
    weights=st.lists(st.integers(1, 100), min_size=1, max_size=24),
    n_clients=st.integers(1, 6),
)
@settings(max_examples=60, deadline=None)
def test_lpt_properties(weights, n_clients):
    w = np.asarray(weights, dtype=np.float64)
    asn = lpt_assign(w, n_clients)
    # every item assigned exactly once
    flat = sorted(i for client in asn for i in client)
    assert flat == list(range(len(w)))
    loads = [sum(w[i] for i in c) for c in asn]
    lb = max(w.sum() / n_clients, w.max())
    assert max(loads) >= lb - 1e-9
    # LPT guarantee: ≤ 4/3 · OPT ≤ 4/3 · (LB + max item slack)
    assert max(loads) <= (4 / 3) * lb + w.max() / 3 + 1e-9


@given(
    weights=st.lists(st.integers(1, 30), min_size=2, max_size=8),
    n_clients=st.integers(2, 3),
)
@settings(max_examples=20, deadline=None)
def test_local_search_never_worse_and_milp_optimal(weights, n_clients):
    w = np.asarray(weights, dtype=np.float64)
    asn = lpt_assign(w, n_clients)
    loads0 = max(sum(w[i] for i in c) for c in asn)
    asn2 = local_search(asn, w)
    loads1 = max(sum(w[i] for i in c) for c in asn2)
    assert loads1 <= loads0 + 1e-9
    # brute force optimum for small instances
    best = np.inf
    for assign in itertools.product(range(n_clients), repeat=len(w)):
        loads = [0.0] * n_clients
        for i, j in enumerate(assign):
            loads[j] += w[i]
        best = min(best, max(loads))
    exact = milp_assign(w, n_clients, time_limit_s=20)
    assert exact is not None
    loads_m = max(sum(w[i] for i in c) for c in exact)
    assert loads_m == pytest.approx(best, rel=1e-9)
    assert loads1 >= best - 1e-9


def test_solve_offline_paper_scale_fast_and_tight():
    from repro.data import gsm8k_like_workload

    reqs = gsm8k_like_workload(seed=0, known_lengths=True)
    res = solve_offline(reqs, 200, PAPER_COST_MODEL)
    assert res.solve_seconds < 10.0
    # LPT + local search lands within a few % of the (loose) LP bound; the
    # paper's exact-SCIP path needed ~20 minutes for this instance.
    assert res.gap < 0.03


def test_lower_bound_below_all_simulations():
    from repro.core import simulate
    from repro.data import WorkloadSpec, gsm8k_like_workload

    spec = WorkloadSpec(n_requests=60, output_max=64, output_mean=30,
                        output_std=15, input_mean=20, input_std=5)
    reqs = gsm8k_like_workload(spec, seed=3, known_lengths=True)
    cm = CostModel(level_caps=(128, 256, 512))
    lb = theoretical_lower_bound(reqs, 8, cm)
    for mode in ("baseline", "offline", "online", "hybrid"):
        tr = simulate(reqs, 8, cm, mode=mode)
        assert tr.makespan >= lb.total * 0.999, mode


# --------------------------------------------------------------------------- #
# Algorithm 1 (sorting + stealing)                                             #
# --------------------------------------------------------------------------- #
def test_sorting_preemptive_sorts_and_steals():
    reqs = make_requests([10, 10, 10, 10], [5, 40, 10, 20])
    clients = build_clients(2, reqs, [[0, 1], [2, 3]])
    sched = SortingPreemptiveScheduler(clients)
    # backlogs sorted by N_p + N_d descending
    assert [r.rid for r in clients[0].backlog] == [1, 0]
    assert [r.rid for r in clients[1].backlog] == [3, 2]
    # client 0 takes its own head
    batch = sched.propose_batch([clients[0]], max_tokens=1000)
    assert batch[0][1].rid == 1
    sched.commit_batch(batch)
    # empty client 0's backlog, then it must steal the longest from client 1
    sched.commit(clients[0], clients[0].backlog[0])
    batch = sched.propose_batch([clients[0]], max_tokens=1000)
    assert batch[0][1].rid == 3  # longest remaining on the most-loaded donor


def test_propose_batch_respects_capacity_and_uniqueness():
    reqs = make_requests([300, 300, 300, 50], [10, 10, 10, 10])
    clients = build_clients(4, reqs, [[0], [1], [2], [3]])
    sched = StaticBacklogScheduler(clients)
    batch = sched.propose_batch(clients, max_tokens=650)
    rids = [r.rid for _, r in batch]
    assert len(set(rids)) == len(rids)
    assert sum(r.n_prefill for _, r in batch) <= 650


# --------------------------------------------------------------------------- #
# Lagrangian iteration rule                                                    #
# --------------------------------------------------------------------------- #
def _snap(cand_reqs, n_active=100, pending=500, n_clients=200):
    cand = CandidateBatch(requests=cand_reqs, client_ids=list(range(len(cand_reqs))))
    return SystemSnapshot(
        n_clients=n_clients, n_active=n_active,
        n_idle=n_clients - n_active,
        active_remaining_est=10_000, pending_requests=pending,
        candidate=cand, now=0.0,
    )


def test_lagrangian_waits_for_amortization_then_fires():
    pol = LagrangianPolicy()
    cm = PAPER_COST_MODEL
    short = make_requests([60], [100])           # C_d = 21ms < C_p(level 512) = 91.6ms
    assert pol(_snap(short), cm) is False
    several = make_requests([60, 60, 60], [300, 300, 300])  # C_d = 189ms > C_p
    assert pol(_snap(several), cm) is True


def test_lagrangian_progress_guards():
    pol = LagrangianPolicy()
    cm = PAPER_COST_MODEL
    # no active decodes → must prefill
    snap = _snap(make_requests([60], [10]), n_active=0)
    assert pol(snap, cm) is True
    # drain phase (pending <= idle) → admit immediately
    snap = _snap(make_requests([60], [10]), n_active=10, pending=1)
    assert pol(snap, cm) is True
    # empty candidate → decode
    snap = _snap([], n_active=10)
    assert pol(snap, cm) is False


def test_prefill_first_always_fires_with_candidate():
    pol = PrefillFirstPolicy()
    assert pol(_snap(make_requests([10], [1])), PAPER_COST_MODEL) is True
