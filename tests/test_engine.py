"""Serving engine end-to-end: real model, real jit steps, scheduler plugged
in, checkpoint round-trip, profiler adaptation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core import (
    CostModel,
    GlobalQueueScheduler,
    LagrangianPolicy,
    PrefillFirstPolicy,
    SortingPreemptiveScheduler,
    build_clients,
    solve_offline,
)
from repro.data import WorkloadSpec, gsm8k_like_workload
from repro.models.layers import init_params
from repro.models.transformer import TransformerLM
from repro.serving.engine import Engine, EngineConfig

CFG = ArchConfig(
    name="demo", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=256,
)
SPEC = WorkloadSpec(
    n_requests=16, input_mean=18, input_std=5, output_mean=16,
    output_std=8, output_max=24, input_max=28,
)
CM = CostModel(level_caps=(32, 64, 128))


@pytest.fixture(scope="module")
def model_and_params():
    model = TransformerLM(CFG)
    params = init_params(jax.random.key(0), model.param_defs())
    return model, params


def _engine(model, params, **kw):
    eng = Engine(
        model, params,
        EngineConfig(n_slots=4, max_len=64, prefill_seq_buckets=(32,), **kw),
    )
    eng.profiler.cost_model = CM
    return eng


def _frozen_engine(model, params, **kw):
    """Engine with the cost model pinned (no online refits): scheduling
    decisions become a deterministic function of the workload, so trace-shape
    assertions (num_bins, utilization) can't flake on machine-load noise."""
    from repro.serving.profiler import OnlineProfiler

    eng = Engine(
        model, params,
        EngineConfig(n_slots=4, max_len=64, prefill_seq_buckets=(32,), **kw),
        profiler=OnlineProfiler(initial=CM, refit_every=10**9),
    )
    return eng


def test_engine_serves_all_requests(model_and_params):
    model, params = model_and_params
    reqs = gsm8k_like_workload(SPEC, seed=0, known_lengths=True)
    clients = build_clients(4, reqs, None)
    eng = _engine(model, params)
    tr = eng.serve(reqs, clients, GlobalQueueScheduler(reqs), PrefillFirstPolicy())
    tr.validate()  # all requests prefilled once, decoded fully
    assert tr.utilization > 0.2
    assert all(eng.slots.request_of[i] is None for i in range(4))  # all released


@pytest.mark.slow
def test_engine_hybrid_beats_baseline(model_and_params):
    model, params = model_and_params
    results = {}
    for mode in ("baseline", "hybrid"):
        reqs = gsm8k_like_workload(SPEC, seed=1, known_lengths=True)
        eng = _frozen_engine(model, params)
        if mode == "baseline":
            clients = build_clients(4, reqs, None)
            sched, pol = GlobalQueueScheduler(reqs), PrefillFirstPolicy()
        else:
            asn = solve_offline(reqs, 4, CM).assignment
            clients = build_clients(4, reqs, asn)
            sched, pol = SortingPreemptiveScheduler(clients), LagrangianPolicy()
        tr = eng.serve(reqs, clients, sched, pol)
        results[mode] = tr
    assert results["hybrid"].num_bins <= results["baseline"].num_bins
    assert results["hybrid"].utilization >= results["baseline"].utilization - 0.02


def test_engine_greedy_decode_matches_model(model_and_params):
    """Tokens the engine produces == tokens from a straight-line greedy
    decode of the same prompt with the raw model (continuous batching must
    not change results)."""
    model, params = model_and_params
    reqs = gsm8k_like_workload(
        WorkloadSpec(n_requests=3, input_mean=12, input_std=2, output_mean=6,
                     output_std=2, output_max=8, input_max=16),
        seed=2, known_lengths=True,
    )
    eng = _engine(model, params)
    clients = build_clients(4, reqs, None)
    captured = {}
    orig_release = eng.slots.release

    def capture_release(slot):
        req = eng.slots.request_of[slot]
        captured.setdefault(req.rid, []).append(slot)
        return orig_release(slot)

    eng.slots.release = capture_release
    tr = eng.serve(reqs, clients, GlobalQueueScheduler(reqs), PrefillFirstPolicy())
    tr.validate()
    # straight-line reference for request 0
    r = reqs[0]
    rng = np.random.default_rng(r.rid)
    prompt = rng.integers(1, CFG.vocab_size, size=r.n_prefill).astype(np.int32)
    seq = list(prompt)
    for _ in range(r.n_decode):
        logits, _ = model.forward(params, jnp.asarray(seq)[None, :], remat=False)
        seq.append(int(jnp.argmax(logits[0, -1])))
    # engine path: replay via slot pending tokens is not recorded per token,
    # so instead check the FIRST generated token via a fresh prefill
    cache = model.cache_init(1, 64)
    lp, _ = model.prefill(
        params, jnp.asarray(prompt)[None, :], cache,
        lengths=jnp.asarray([r.n_prefill], jnp.int32),
    )
    assert int(jnp.argmax(lp[0])) == seq[len(prompt)]


def test_engine_checkpoint_roundtrip(model_and_params, tmp_path):
    from repro.checkpoint import restore_checkpoint, save_checkpoint

    model, params = model_and_params
    reqs = gsm8k_like_workload(SPEC, seed=3, known_lengths=True)
    eng = _engine(model, params)
    clients = build_clients(4, reqs, None)
    eng.serve(reqs, clients, GlobalQueueScheduler(reqs), PrefillFirstPolicy())
    state = eng.state_dict()
    save_checkpoint(tmp_path, 1, state)
    eng2 = _engine(model, params)
    restored, _ = restore_checkpoint(tmp_path, 1, eng2.state_dict())
    eng2.load_state_dict(restored, {r.rid: r for r in reqs})
    for a, b in zip(
        jax.tree_util.tree_leaves(eng.slots.cache),
        jax.tree_util.tree_leaves(eng2.slots.cache),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_profiler_adapts_cost_model():
    from repro.serving.profiler import OnlineProfiler

    prof = OnlineProfiler(initial=CostModel(level_caps=(64, 128)), refit_every=4)
    true = CostModel(
        prefill_per_token=2e-3, prefill_overhead=5e-3,
        decode_per_token=1e-3, decode_overhead=2e-3, level_caps=(64, 128),
    )
    for n in (16, 32, 48, 64, 16, 32):
        prof.record_prefill(n, true.prefill_time(n))
        prof.record_decode(n // 8, true.decode_round_time(n // 8))
    assert prof.fits >= 1
    assert prof.cost_model.prefill_per_token == pytest.approx(2e-3, rel=1e-3)
    assert prof.cost_model.decode_overhead == pytest.approx(2e-3, rel=1e-3)
