"""Workload calibration, MIP model, HLO analyzer, MoE dispatch properties,
ring-cache properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis"
)
from hypothesis import given, settings, strategies as st

from repro.core import OriginalMIP, recost_trace_mip_semantics, simulate, toy_instance
from repro.data import PAPER_WORKLOAD_SPEC, gsm8k_like_workload


def test_workload_matches_paper_moments():
    reqs = gsm8k_like_workload(seed=0)
    p = np.asarray([r.n_prefill for r in reqs])
    d = np.asarray([r.n_decode for r in reqs])
    assert len(reqs) == 1319
    assert abs(p.mean() - 68.43) < 3.0
    assert abs(p.std() - 25.04) < 3.0
    assert abs(d.mean() - 344.83) < 18.0
    assert abs(d.std() - 187.99) < 12.0
    assert d.max() <= 512 and d.min() >= 1


def test_mip_toy_optimal_and_feasible():
    reqs, J, K, cm = toy_instance(seed=0)
    m = OriginalMIP(reqs, J, K, cm)
    sol = m.solve(time_limit_s=60)
    assert sol.status == "optimal"
    m.check_solution(sol)
    tr = simulate(reqs, J, cm, mode="hybrid", oracle_estimates=True)
    hyb = recost_trace_mip_semantics(tr, cm, J)
    assert hyb >= sol.objective - 1e-9          # MIP is a valid lower bound
    assert hyb <= sol.objective * 1.25          # heuristic near-optimal


def test_mip_lp_relaxation_bounds_mip():
    reqs, J, K, cm = toy_instance(seed=1)
    m = OriginalMIP(reqs, J, K, cm)
    sol = m.solve(time_limit_s=60)
    rel = m.solve(time_limit_s=60, relax=True)
    assert rel.objective <= sol.objective + 1e-9


def test_hlo_analyzer_counts_nested_loops():
    from repro.launch.hlo_analysis import analyze_hlo

    def f(x, ws):
        def outer(h, _):
            def body(h, w):
                return h @ w, None

            h, _ = jax.lax.scan(body, h, ws)
            return h, None

        h, _ = jax.lax.scan(outer, x, None, length=5)
        return h

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)
    compiled = jax.jit(f).lower(x, ws).compile()
    t = analyze_hlo(compiled.as_text())
    expected = 5 * 10 * 2 * 128**3
    assert abs(t.flops - expected) / expected < 0.05


@given(
    choices=st.lists(st.integers(0, 7), min_size=1, max_size=64),
)
@settings(max_examples=50, deadline=None)
def test_moe_ranks_property(choices):
    """Every (token, expert) choice gets a unique rank within its expert,
    ranks are dense from 0, and priority follows token order."""
    from repro.models.moe import _ranks_within_expert

    fc = jnp.asarray(choices, jnp.int32)
    ranks = np.asarray(_ranks_within_expert(fc, 8))
    for e in range(8):
        rs = ranks[np.asarray(choices) == e]
        assert sorted(rs.tolist()) == list(range(len(rs)))
        # priority = appearance order
        assert rs.tolist() == sorted(rs.tolist())


@given(
    window=st.integers(2, 16),
    lengths=st.lists(st.integers(0, 64), min_size=1, max_size=4),
)
@settings(max_examples=50, deadline=None)
def test_ring_positions_property(window, lengths):
    """Slot map holds exactly the last min(len, W) positions, each in its
    p % W slot."""
    from repro.models.cache import ring_positions_prefill

    lens = jnp.asarray(lengths, jnp.int32)
    pos = np.asarray(ring_positions_prefill(len(lengths), window, lens))
    for b, L in enumerate(lengths):
        want = {p for p in range(max(0, L - window), L)}
        got = {int(p) for p in pos[b] if p >= 0}
        assert got == want
        for z in range(window):
            if pos[b, z] >= 0:
                assert pos[b, z] % window == z


def test_sampler_top_p_valid_tokens():
    from repro.serving.sampler import greedy, sample_top_p

    logits = jax.random.normal(jax.random.key(0), (4, 50))
    g = greedy(logits)
    assert g.shape == (4,) and int(g.max()) < 50
    t = sample_top_p(logits, jax.random.key(1), top_p=0.8)
    assert t.shape == (4,) and int(t.max()) < 50
    # top-p with tiny p == greedy
    t2 = sample_top_p(logits, jax.random.key(2), top_p=1e-6)
    np.testing.assert_array_equal(np.asarray(t2), np.asarray(g))


def test_dryrun_collective_accounting_nonzero():
    """Sanity on the saved dry-run artifacts (if the sweep has produced
    them): every ok cell accounts flops and the trainers account
    collectives."""
    import json
    from pathlib import Path

    d = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"
    files = list(d.glob("*16x16*.json")) if d.exists() else []
    if not files:
        pytest.skip("dry-run artifacts not generated yet")
    for f in files:
        r = json.loads(f.read_text())
        if r.get("status") != "ok":
            continue
        assert r["cost"]["flops"] > 0, f.name
        if r["shape"] == "train_4k":
            assert r["collective_bytes_total"] > 0, f.name
