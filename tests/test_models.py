"""Per-architecture smoke tests (reduced configs, CPU): one forward/train
step asserting shapes + finiteness, plus prefill/decode vs full-forward
consistency for each family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models.layers import abstract_params, init_params, logical_specs
from repro.models.registry import get_model
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.train_step import make_train_step

B, S = 2, 16

# The big recurrent/audio configs dominate suite wall-clock; their smoke
# params carry the slow marker (CI's bench-smoke job runs them) while the
# cheap architectures keep every-run coverage.
_HEAVY_ARCHS = {"recurrentgemma_9b", "xlstm_350m", "whisper_small"}
ARCH_PARAMS = [
    pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY_ARCHS else a
    for a in ARCH_IDS
]


def _batch(cfg, seed=0):
    rng = np.random.default_rng(seed)
    tokens = rng.integers(0, cfg.vocab_size, size=(B, S)).astype(np.int32)
    batch = {
        "tokens": jnp.asarray(tokens),
        "labels": jnp.asarray(np.roll(tokens, -1, axis=1)),
    }
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)).astype(np.float32)
        )
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_patch_tokens, cfg.d_model)).astype(np.float32)
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    params = init_params(jax.random.key(0), model.param_defs())
    batch = _batch(cfg)
    loss = model.loss(params, batch, remat=False)
    assert jnp.isfinite(loss), f"{arch}: loss not finite"
    # one full train step (grads + AdamW) stays finite and changes params
    step = make_train_step(model, AdamWConfig(lr=1e-3, warmup_steps=1), microbatches=2)
    opt = adamw_init(params)
    new_params, new_opt, metrics = jax.jit(step)(params, opt, batch)
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"]) and float(metrics["grad_norm"]) > 0
    leaf0 = jax.tree_util.tree_leaves(params)[0]
    leaf1 = jax.tree_util.tree_leaves(new_params)[0]
    assert leaf0.shape == leaf1.shape
    assert int(new_opt["count"]) == 1


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_smoke_serve_consistency(arch):
    """prefill + one decode step == full forward on the extended sequence."""
    cfg = get_smoke_config(arch)
    import dataclasses

    cfg = dataclasses.replace(cfg, dtype="float32", moe_capacity_factor=8.0)
    model = get_model(cfg)
    params = init_params(jax.random.key(0), model.param_defs())
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(B, S)), jnp.int32)
    frames = None
    if cfg.family == "audio":
        frames = jnp.asarray(rng.normal(size=(B, 10, cfg.d_model)), jnp.float32)
        cache = model.cache_init(B, 32, enc_len=10)
        lp, cache = model.prefill(params, tokens, cache, patch_embeds=frames)
        lf, _ = model.forward(params, {"frames": frames, "tokens": tokens}, remat=False)
    elif cfg.family == "vlm":
        frames = jnp.asarray(
            rng.normal(size=(B, cfg.num_patch_tokens, cfg.d_model)), jnp.float32
        )
        cache = model.cache_init(B, 32)
        lp, cache = model.prefill(params, tokens, cache, patch_embeds=frames)
        lf, _ = model.forward(params, tokens, patch_embeds=frames, remat=False)
    else:
        cache = model.cache_init(B, 32)
        lp, cache = model.prefill(params, tokens, cache)
        lf, _ = model.forward(params, tokens, remat=False)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lf[:, -1, :]), rtol=2e-3, atol=2e-3)

    nxt = jnp.argmax(lp, -1).astype(jnp.int32)
    ld, cache = model.decode_step(params, nxt, cache)
    ext = jnp.concatenate([tokens, nxt[:, None]], axis=1)
    if cfg.family == "audio":
        lf2, _ = model.forward(params, {"frames": frames, "tokens": ext}, remat=False)
    elif cfg.family == "vlm":
        lf2, _ = model.forward(params, ext, patch_embeds=frames, remat=False)
    else:
        lf2, _ = model.forward(params, ext, remat=False)
    np.testing.assert_allclose(np.asarray(ld), np.asarray(lf2[:, -1, :]), rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_abstract_params_match_real(arch):
    """Dry-run stand-ins exactly mirror real parameter shapes/dtypes."""
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    defs = model.param_defs()
    abstract = abstract_params(defs)
    real = init_params(jax.random.key(0), defs)
    flat_a = jax.tree_util.tree_leaves(abstract)
    flat_r = jax.tree_util.tree_leaves(real)
    assert len(flat_a) == len(flat_r)
    for a, r in zip(flat_a, flat_r):
        assert a.shape == r.shape and a.dtype == r.dtype
    # logical axes rank-match every leaf
    for axes, leaf in zip(
        jax.tree_util.tree_leaves(
            logical_specs(defs),
            is_leaf=lambda x: isinstance(x, tuple) and all(
                e is None or isinstance(e, str) for e in x
            ),
        ),
        flat_r,
    ):
        assert len(axes) == leaf.ndim


@pytest.mark.slow
def test_ragged_continuous_batching_dense():
    """Engine contract: ragged prefill lengths + per-slot decode positions."""
    from repro.configs.base import ArchConfig
    from repro.models.transformer import TransformerLM

    cfg = ArchConfig(name="t", family="dense", n_layers=2, d_model=64, n_heads=4,
                     n_kv_heads=2, d_ff=128, vocab_size=128, dtype="float32")
    m = TransformerLM(cfg)
    params = init_params(jax.random.key(0), m.param_defs())
    tokens = jax.random.randint(jax.random.key(1), (3, 12), 0, 128)
    lengths = jnp.array([12, 9, 7], jnp.int32)
    tokens = jnp.where(jnp.arange(12)[None, :] < lengths[:, None], tokens, 0)
    cache = m.cache_init(3, 32)
    lp, cache = m.prefill(params, tokens, cache, lengths=lengths)
    for b in range(3):
        L = int(lengths[b])
        lf, _ = m.forward(params, tokens[b : b + 1, :L], remat=False)
        np.testing.assert_allclose(np.asarray(lp[b]), np.asarray(lf[0, -1]), rtol=1e-3, atol=1e-3)
    toks = jnp.argmax(lp, -1).astype(jnp.int32)
    seqs = [list(np.asarray(tokens[b, : int(lengths[b])])) for b in range(3)]
    for _ in range(3):
        for b in range(3):
            seqs[b].append(int(toks[b]))
        ld, cache = m.decode_step(params, toks, cache)
        for b in range(3):
            lf, _ = m.forward(params, jnp.asarray(seqs[b])[None, :], remat=False)
            np.testing.assert_allclose(
                np.asarray(ld[b]), np.asarray(lf[0, -1]), rtol=2e-3, atol=2e-3
            )
        toks = jnp.argmax(ld, -1).astype(jnp.int32)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["xlstm_350m", "recurrentgemma_9b"])
def test_ragged_continuous_batching_recurrent(arch):
    """Recurrent families honor per-slot prompt lengths: pad tokens never
    touch a slot's state (engine continuous-batching contract)."""
    import dataclasses

    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    model = get_model(cfg)
    params = init_params(jax.random.key(0), model.param_defs())
    tokens = jax.random.randint(jax.random.key(1), (3, 12), 0, cfg.vocab_size)
    lengths = jnp.array([12, 9, 7], jnp.int32)
    tokens = jnp.where(jnp.arange(12)[None, :] < lengths[:, None], tokens, 0)
    cache = (model.cache_init(3) if cfg.family == "ssm" else model.cache_init(3, 32))
    lp, cache = model.prefill(params, tokens, cache, lengths=lengths)
    for b in range(3):
        L = int(lengths[b])
        lf, _ = model.forward(params, tokens[b : b + 1, :L], remat=False)
        np.testing.assert_allclose(
            np.asarray(lp[b]), np.asarray(lf[0, -1]), rtol=2e-3, atol=2e-3
        )
    toks = jnp.argmax(lp, -1).astype(jnp.int32)
    seqs = [list(np.asarray(tokens[b, : int(lengths[b])])) for b in range(3)]
    for _ in range(3):
        for b in range(3):
            seqs[b].append(int(toks[b]))
        ld, cache = model.decode_step(params, toks, cache)
        for b in range(3):
            lf, _ = model.forward(params, jnp.asarray(seqs[b])[None, :], remat=False)
            np.testing.assert_allclose(
                np.asarray(ld[b]), np.asarray(lf[0, -1]), rtol=3e-3, atol=3e-3
            )
        toks = jnp.argmax(ld, -1).astype(jnp.int32)
