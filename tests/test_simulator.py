"""Simulator behaviour: invariants, mode ordering, paper reproduction bands,
and hypothesis properties over random workloads."""
import pytest
hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis"
)
from hypothesis import given, settings, strategies as st

from repro.core import CostModel, PAPER_COST_MODEL, simulate, theoretical_lower_bound
from repro.core.gantt import ascii_gantt, client_accounting, stage_csv, utilization_timeline
from repro.core.types import make_requests
from repro.data import PAPER_PREDICTOR_NOISE_STD, gsm8k_like_workload, WorkloadSpec

SMALL_CM = CostModel(level_caps=(64, 128, 256, 512))


@given(
    n=st.integers(2, 30),
    j=st.integers(1, 6),
    seed=st.integers(0, 10_000),
    mode=st.sampled_from(["baseline", "offline", "online", "hybrid"]),
)
@settings(max_examples=40, deadline=None)
def test_simulation_invariants_random(n, j, seed, mode):
    import numpy as np

    rng = np.random.default_rng(seed)
    reqs = make_requests(
        rng.integers(1, 200, size=n).tolist(), rng.integers(1, 60, size=n).tolist()
    )
    tr = simulate(reqs, j, SMALL_CM, mode=mode)
    # trace.validate() ran inside; re-check headline invariants
    assert 0.0 < tr.utilization <= 1.0
    assert tr.makespan > 0
    assert all(r.t_done is not None and r.decoded == r.n_decode for r in tr.requests)
    # tokens conserved
    assert tr.total_generated_tokens == sum(r.n_decode for r in reqs)
    # every prefill stage within the largest level capacity, except singleton
    # oversize requests (engine contract)
    for s in tr.stages:
        if s.kind.value == "prefill" and len(s.busy) > 1:
            assert s.tokens <= SMALL_CM.max_level.cap_tokens


def test_paper_reproduction_bands():
    """The four configurations land within ±3pp / ±8% of the paper's numbers
    and preserve its ordering (see EXPERIMENTS.md for exact values)."""
    reqs = gsm8k_like_workload(seed=0, estimate_noise_std=PAPER_PREDICTOR_NOISE_STD)
    results = {}
    for mode in ("baseline", "offline", "online", "hybrid"):
        tr = simulate(reqs, 200, PAPER_COST_MODEL, mode=mode)
        results[mode] = (tr.utilization * 100, tr.makespan)
    paper = {
        "baseline": (80.2, 201.0),
        "offline": (85.5, 197.08),
        "online": (86.19, 193.33),
        "hybrid": (89.06, 190.58),
    }
    for mode, (pu, pt) in paper.items():
        u, t = results[mode]
        assert abs(u - pu) < 3.0, f"{mode}: util {u:.2f} vs paper {pu}"
        assert abs(t - pt) / pt < 0.08, f"{mode}: time {t:.2f} vs paper {pt}"
    # ordering: baseline < offline < online < hybrid (utilization)
    assert results["baseline"][0] < results["offline"][0]
    assert results["offline"][0] < results["online"][0] + 1.5  # near-tied ok
    assert results["online"][0] < results["hybrid"][0]
    # hybrid strictly dominates baseline in both metrics
    assert results["hybrid"][1] < results["baseline"][1]


def test_decision_latency_budget():
    reqs = gsm8k_like_workload(seed=1, estimate_noise_std=PAPER_PREDICTOR_NOISE_STD)
    tr = simulate(reqs, 200, PAPER_COST_MODEL, mode="hybrid")
    assert max(tr.decision_times_ms) < 10.0      # the paper's hard budget
    assert sorted(tr.decision_times_ms)[len(tr.decision_times_ms) // 2] < 5.0


def test_gantt_renders():
    reqs = gsm8k_like_workload(
        WorkloadSpec(n_requests=20, output_max=32, output_mean=16, output_std=8,
                     input_mean=16, input_std=4),
        seed=0,
    )
    tr = simulate(reqs, 4, SMALL_CM, mode="hybrid")
    g = ascii_gantt(tr, width=40, max_clients=4)
    assert "makespan" in g and "#" in g
    csv = stage_csv(tr)
    assert csv.startswith("kind,")
    acct = client_accounting(tr)
    assert len(acct) == 4
    tl = utilization_timeline(tr, 10)
    assert len(tl) == 10 and all(0 <= u <= 1.001 for u in tl)


def test_oracle_estimates_copy_requests():
    reqs = gsm8k_like_workload(
        WorkloadSpec(n_requests=10, output_max=32, output_mean=16, output_std=8,
                     input_mean=16, input_std=4),
        seed=0,
    )
    before = [r.n_decode_est for r in reqs]
    simulate(reqs, 2, SMALL_CM, mode="hybrid", oracle_estimates=True)
    assert [r.n_decode_est for r in reqs] == before  # caller's requests untouched
    assert all(r.t_done is None for r in reqs)       # bookkeeping untouched
