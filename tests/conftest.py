import os
import sys

# Tests must see ONE CPU device (smoke realism); the dry-run sets its own
# XLA_FLAGS in subprocesses. Ensure src is importable regardless of cwd.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Every engine built under pytest runs with allocator-consistency and
# host<->device block-table mirror checks at stage boundaries (benchmarks
# leave this off; EngineConfig.debug_invariants=False opts a test out).
os.environ.setdefault("REPRO_DEBUG_INVARIANTS", "1")
