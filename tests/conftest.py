import os
import sys

# Tests must see ONE CPU device (smoke realism); the dry-run sets its own
# XLA_FLAGS in subprocesses. Ensure src is importable regardless of cwd.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
