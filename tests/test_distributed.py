"""Distribution-layer tests. Mesh-dependent tests run in subprocesses with
fake devices (XLA device count is locked at first jax init — the main pytest
process stays at 1 CPU device)."""
import json
import subprocess
import sys
import textwrap

import pytest


def _run_fake_devices(script: str, n_devices: int = 8, timeout: int = 360) -> str:
    env = {
        "XLA_FLAGS": f"--xla_force_host_platform_device_count={n_devices}",
        "PYTHONPATH": "src",
        "PATH": "/usr/bin:/bin",
        "HOME": "/tmp",
    }
    import os

    env["PATH"] = os.environ.get("PATH", env["PATH"])
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True, text=True, timeout=timeout,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env={**os.environ, **env},
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


def test_spec_builder_shape_checks():
    """Pure sharding-rule logic (no mesh state needed beyond construction)."""
    out = _run_fake_devices("""
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.distributed.sharding import ShardingConfig, _spec_for
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        scfg = ShardingConfig()
        rules = scfg.rules()
        # weight (embed, mlp): fsdp over data + tp over model
        s = _spec_for((64, 128), ("embed", "mlp"), rules, mesh, True, ("data",))
        assert s == P("data", "model"), s
        # vocab-dim weight: no fsdp on embed
        s = _spec_for((100, 64), ("vocab", "embed"), rules, mesh, True, ("data",))
        assert s == P("model"), s
        # non-divisible dims degrade to replication (batch=1)
        s = _spec_for((1, 7), ("batch", "mlp"), rules, mesh, False, ("data",))
        assert s == P(), s
        # an axis is never used twice
        s = _spec_for((8, 8, 8), ("experts", "mlp", "heads"), rules, mesh, False, ("data",))
        assert str(s).count("model") == 1, s
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_small_mesh_train_and_serve_lower():
    """A miniature end-to-end dry-run on an 8-device (4×2) mesh: train and
    decode steps lower+compile with the production sharding rules."""
    out = _run_fake_devices("""
        import jax, jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.models.registry import get_model
        from repro.models.layers import abstract_params, logical_specs
        from repro.distributed.sharding import (ShardingConfig, build_param_specs,
                                                build_cache_specs)
        from repro.train.optimizer import AdamWConfig, abstract_opt_state
        from repro.train.train_step import make_train_step

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        scfg = ShardingConfig()
        cfg = get_smoke_config("qwen3_8b")
        model = get_model(cfg)
        defs = model.param_defs()
        ap = abstract_params(defs); la = logical_specs(defs)
        ps = build_param_specs(ap, la, mesh, scfg)
        oa = abstract_opt_state(ap)
        osd = {"m": build_param_specs(oa["m"], la, mesh, scfg),
               "v": build_param_specs(oa["v"], la, mesh, scfg),
               "count": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())}
        batch = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
        bs = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec("data"))
        step = make_train_step(model, AdamWConfig(), microbatches=2)
        with mesh:
            c = jax.jit(step, in_shardings=(ps, osd, {"tokens": bs, "labels": bs}),
                        donate_argnums=(0, 1)).lower(ap, oa, batch).compile()
        assert c.memory_analysis().temp_size_in_bytes > 0
        # decode
        cache = model.cache_shape(8, 64)
        cs = build_cache_specs(cache, mesh, scfg, cfg.n_kv_heads)
        tok = jax.ShapeDtypeStruct((8,), jnp.int32)
        ts = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec("data"))
        fn = lambda p, t, c: model.decode_step(p, t, c)
        with mesh:
            c2 = jax.jit(fn, in_shardings=(ps, ts, cs), donate_argnums=(2,)).lower(
                ap, tok, cache).compile()
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_compressed_dp_train_step_numerics():
    """shard_map DP training with int8 error-feedback compression tracks the
    uncompressed path."""
    out = _run_fake_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.models.registry import get_model
        from repro.models.layers import init_params
        from repro.train.dp_compressed import make_dp_train_step, init_error_feedback
        from repro.train.optimizer import AdamWConfig, adamw_init

        mesh = jax.make_mesh((8,), ("data",))
        cfg = get_smoke_config("granite_3_8b")
        import dataclasses
        cfg = dataclasses.replace(cfg, dtype="float32")
        model = get_model(cfg)
        params = init_params(jax.random.key(0), model.param_defs())
        rng = np.random.default_rng(0)
        tokens = rng.integers(0, cfg.vocab_size, size=(16, 16)).astype(np.int32)
        batch = {"tokens": jnp.asarray(tokens),
                 "labels": jnp.asarray(np.roll(tokens, -1, 1))}
        opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=1)
        s_c = make_dp_train_step(model, mesh, opt_cfg, compress=True)
        s_u = make_dp_train_step(model, mesh, opt_cfg, compress=False)
        # independent copies: the steps donate their inputs
        copy = lambda t: jax.tree_util.tree_map(jnp.copy, t)
        pc, oc, e = copy(params), adamw_init(copy(params)), init_error_feedback(params)
        pu, ou = copy(params), adamw_init(copy(params))
        for _ in range(3):
            pc, oc, e, mc = s_c(pc, oc, e, batch)
            pu, ou, _, mu = s_u(pu, ou, init_error_feedback(params), batch)
        # same loss trajectory within quantization noise
        assert abs(float(mc["loss"]) - float(mu["loss"])) < 0.05, (mc["loss"], mu["loss"])
        l1 = jax.tree_util.tree_leaves(pc)[3]; l2 = jax.tree_util.tree_leaves(pu)[3]
        diff = float(jnp.max(jnp.abs(l1 - l2)))
        # Adam normalizes step sizes to ~lr, so after 3 steps the compressed
        # trajectory may deviate by a few lr's worth of quantization noise;
        # error feedback bounds it (it does not grow with steps — see the
        # accumulation test in test_checkpoint_and_train).
        assert diff < 3 * 3 * 1e-3, diff
        print("OK")
    """)
    assert "OK" in out


def test_elastic_remesh_and_restore(tmp_path):
    """Checkpoint on a 2×4 mesh, lose half the fleet, restore onto 1×4 —
    values identical, shardings valid on the new mesh."""
    out = _run_fake_devices(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro.checkpoint import save_checkpoint
        from repro.configs import get_smoke_config
        from repro.distributed.elastic import build_mesh, remesh_plan, reshard_restore
        from repro.distributed.sharding import ShardingConfig, build_param_specs
        from repro.models.layers import abstract_params, init_params, logical_specs
        from repro.models.registry import get_model

        cfg = get_smoke_config("qwen3_8b")
        model = get_model(cfg)
        defs = model.param_defs()
        params = init_params(jax.random.key(0), defs)
        mesh1 = jax.make_mesh((4, 2), ("data", "model"))
        save_checkpoint(r"{tmp_path}", 7, params)

        plan = remesh_plan((4, 2), ("data", "model"), n_healthy=4)
        assert plan.new_shape == (2, 2), plan
        mesh2 = build_mesh(plan)
        ap = abstract_params(defs)
        la = logical_specs(defs)
        restored, meta = reshard_restore(r"{tmp_path}", ap, la, mesh2)
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))
            assert len(b.sharding.mesh.axis_names) == 2
        print("OK")
    """)
    assert "OK" in out


def test_remesh_plan_preserves_model_axis():
    from repro.distributed.elastic import remesh_plan

    plan = remesh_plan((2, 16, 16), ("pod", "data", "model"), n_healthy=300)
    assert plan.new_shape[2] == 16                      # TP width preserved
    import numpy as np

    assert np.prod(plan.new_shape) <= 300
    assert np.prod(plan.new_shape) == 256               # largest pow2 fit
    with pytest.raises(ValueError):
        remesh_plan((2, 16, 16), ("pod", "data", "model"), n_healthy=8)
