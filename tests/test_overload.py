"""Overload control: allocator/slot invariants under preemption churn,
SLO-aware admission throttling, deadline-online queue bypass, and
fault-tolerant fleet recovery with exactly-once token streams."""
import random

import jax
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core import (
    ArrivalQueueScheduler,
    ClientState,
    CostModel,
    GlobalQueueScheduler,
    LagrangianPolicy,
    Request,
    build_clients,
)
from repro.core.online import SortingPreemptiveScheduler
from repro.models.layers import init_params
from repro.models.transformer import TransformerLM
from repro.serving.engine import Engine, EngineConfig
from repro.serving.fleet import FaultPlan, Fleet, FleetConfig, ReplicaFault
from repro.serving.kv_slots import BlockAllocator, PagedSlotManager
from repro.serving.overload import OverloadPolicy, SLOAwareOverloadPolicy

CFG = ArchConfig(
    name="demo", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=256,
)
CM = CostModel(level_caps=(32, 64, 128))


@pytest.fixture(scope="module")
def model_and_params():
    model = TransformerLM(CFG)
    params = init_params(jax.random.key(0), model.param_defs())
    return model, params


def _engine(model, params, overload=None, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("prefill_seq_buckets", (32,))
    kw.setdefault("kv_layout", "paged")
    kw.setdefault("page_size", 16)
    kw.setdefault("prefill_chunk", 16)
    eng = Engine(model, params, EngineConfig(**kw), overload_policy=overload)
    eng.profiler.cost_model = CM
    return eng


def _serve(eng, reqs, scheduler=None):
    clients = build_clients(eng.cfg.n_slots, reqs, None)
    sched = scheduler if scheduler is not None else GlobalQueueScheduler(reqs)
    return eng.serve(reqs, clients, sched, LagrangianPolicy())


# --------------------------------------------------------------------------- #
# BlockAllocator invariants                                                   #
# --------------------------------------------------------------------------- #
def test_allocator_rejects_double_free():
    alloc = BlockAllocator(num_pages=8, page_size=16)
    pages = alloc.allocate(3)
    alloc.free(pages)
    with pytest.raises(RuntimeError, match="double free"):
        alloc.free(pages[:1])


def test_allocator_rejects_out_of_range_free():
    alloc = BlockAllocator(num_pages=8, page_size=16)
    with pytest.raises(ValueError, match="out of range"):
        alloc.free([8])


def test_allocator_reset_in_use_round_trips():
    alloc = BlockAllocator(num_pages=10, page_size=8)
    held = alloc.allocate(4)
    alloc.reset(in_use=held)
    alloc.check_consistency()
    assert alloc.num_used == 4
    assert alloc.num_free == 6
    # the held pages are NOT in the rebuilt free list: freeing them is legal,
    # freeing them twice is not
    alloc.free(held)
    assert alloc.num_free == 10
    with pytest.raises(RuntimeError, match="double free"):
        alloc.free(held[:2])


def test_allocator_random_churn_never_diverges():
    rng = random.Random(0)
    alloc = BlockAllocator(num_pages=24, page_size=8)
    owned = []                       # list of page-lists, one per fake slot
    for _ in range(500):
        if owned and rng.random() < 0.45:
            alloc.free(owned.pop(rng.randrange(len(owned))))
        else:
            want = rng.randint(1, 4)
            if alloc.can_allocate(want):
                owned.append(alloc.allocate(want))
        alloc.check_consistency()
        flat = [p for ps in owned for p in ps]
        assert len(flat) == len(set(flat))                 # no double-owned
        assert len(flat) + alloc.num_free == alloc.num_pages   # no leak


# --------------------------------------------------------------------------- #
# PagedSlotManager ownership under reserve/grow/evict churn                   #
# --------------------------------------------------------------------------- #
def _assert_page_ownership(slots: PagedSlotManager):
    flat = [p for t in slots.tables for p in t]
    assert len(flat) == len(set(flat)), "page owned by two slots"
    assert len(flat) + slots.allocator.num_free == slots.allocator.num_pages
    slots.allocator.check_consistency()
    # device block tables mirror the host tables exactly
    bt = np.asarray(slots.cache["block_tables"])
    for s, t in enumerate(slots.tables):
        assert [int(p) for p in bt[s] if p >= 0] == t


def test_paged_slots_reserve_grow_evict_churn(model_and_params):
    model, params = model_and_params
    rng = random.Random(7)
    slots = PagedSlotManager(model, n_slots=4, max_len=64, page_size=8,
                             num_pages=16)
    toks = [0] * 4
    for step in range(300):
        s = rng.randrange(4)
        if slots.request_of[s] is None:
            if rng.random() < 0.7:
                req = Request(rid=1000 + step, n_prefill=8, n_decode=8)
                slots.bind(s, req)
                n = rng.randint(1, 24)
                if slots.allocator.can_allocate(slots.allocator.pages_for(n)):
                    slots.reserve(s, n)
                    toks[s] = n
                else:
                    slots.request_of[s] = None     # admission backpressure
        else:
            roll = rng.random()
            if roll < 0.4:                          # decode growth
                want = min(toks[s] + rng.randint(1, 12), slots.max_len)
                if slots.allocator.can_allocate(slots.pages_to_cover(s, want)):
                    slots.ensure_tokens(s, want)
                    toks[s] = want
            elif roll < 0.7:                        # eviction (preemption)
                slots.free_pages_of(s)
                slots.request_of[s] = None
                toks[s] = 0
            else:                                   # normal completion
                slots.release(s)
                toks[s] = 0
        _assert_page_ownership(slots)


# --------------------------------------------------------------------------- #
# Preemption-by-eviction: bit-identical streams at random preemption points   #
# --------------------------------------------------------------------------- #
def test_preemption_streams_bit_identical_across_pool_sizes(model_and_params):
    """Sweep pool sizes so preemption fires at different (workload-determined)
    points; every serve must emit exactly the streams of the uncontended
    pool. Decode lengths are staggered so victims hold partial prefixes."""
    model, params = model_and_params

    def reqs():
        return [
            Request(rid=i, n_prefill=12, n_decode=16 + 6 * (i % 3))
            for i in range(6)
        ]

    def serve_with_pool(num_pages):
        eng = _engine(model, params, n_slots=4, page_size=8,
                      num_pages=num_pages)
        _serve(eng, reqs())                                    # warm
        trace = _serve(eng, reqs())
        _assert_page_ownership(eng.slots)                      # drained clean
        assert eng.slots.allocator.num_used == 0
        return eng, trace

    ref_eng, ref_trace = serve_with_pool(None)                 # full capacity
    assert ref_eng.preemption_events == 0
    preempted_somewhere = False
    for num_pages in (16, 14, 12, 10):
        eng, trace = serve_with_pool(num_pages)
        trace.validate()
        assert eng.generated.keys() == ref_eng.generated.keys()
        for rid in ref_eng.generated:
            assert eng.generated[rid] == ref_eng.generated[rid], (
                f"stream diverged for rid {rid} at num_pages={num_pages}"
            )
        preempted_somewhere |= eng.preemption_events > 0
    assert preempted_somewhere, "sweep never exercised preemption"


# --------------------------------------------------------------------------- #
# Admission: deadline-online bypasses a deferred offline head (no livelock)   #
# --------------------------------------------------------------------------- #
def test_propose_batch_exclude_skips_queue_head():
    reqs = [
        Request(rid=0, n_prefill=8, n_decode=4),               # offline head
        Request(rid=1, n_prefill=8, n_decode=4, arrival=0.0, ttft_slo_s=1.0),
    ]
    sched = GlobalQueueScheduler(reqs)
    clients = [ClientState(cid=0), ClientState(cid=1)]
    plain = sched.propose_batch(clients, 64)
    assert [r.rid for _, r in plain] == [0, 1]
    bypass = sched.propose_batch(clients, 64, exclude={0})
    assert [r.rid for _, r in bypass] == [1]


def test_sorting_scheduler_propose_batch_accepts_exclude():
    reqs = [Request(rid=i, n_prefill=8, n_decode=4) for i in range(3)]
    clients = [ClientState(cid=0, backlog=list(reqs))]
    sched = SortingPreemptiveScheduler(clients)
    got = sched.propose_batch(clients, 64, exclude={reqs[0].rid})
    assert reqs[0].rid not in {r.rid for _, r in got}


def test_deferred_offline_head_does_not_starve_online(model_and_params):
    """An SLO-aware engine deferring its offline FCFS head must still admit
    the online request queued behind it the same round — and the deferred
    offline work must still complete once online traffic drains."""
    model, params = model_and_params
    eng = _engine(model, params)
    warm = [Request(rid=i, n_prefill=12, n_decode=8) for i in range(4)]
    _serve(eng, warm, ArrivalQueueScheduler(warm))
    eng.warm_serving_shapes()

    pol = SLOAwareOverloadPolicy()
    eng.overload = pol
    reqs = [Request(rid=i, n_prefill=12, n_decode=8) for i in range(4)]
    reqs.append(Request(rid=100, n_prefill=12, n_decode=8, arrival=1e-7,
                        ttft_slo_s=10.0))
    trace = eng.serve(reqs, build_clients(2, reqs, None),
                      ArrivalQueueScheduler(reqs), LagrangianPolicy())
    trace.validate()                       # every request completed exactly once
    assert pol.deferrals > 0, "policy never engaged"
    online = next(r for r in trace.requests if r.rid == 100)
    offline_first_starts = sorted(
        r.t_prefill_start for r in trace.requests if r.rid != 100
    )
    # the online request did not wait for the whole deferred backlog: at
    # least one offline request prefilled AFTER it (bypass, not FIFO drain)
    assert online.t_prefill_start < offline_first_starts[-1]


# --------------------------------------------------------------------------- #
# SLOAwareOverloadPolicy unit behavior                                        #
# --------------------------------------------------------------------------- #
class _FakeEngine:
    def __init__(self, queued=()):
        self._queued = tuple(queued)

    def queued_requests(self):
        return self._queued


def _pairs(*reqs):
    return [(object(), r) for r in reqs]


def test_policy_passthrough_without_offline_pairs():
    pol = SLOAwareOverloadPolicy()
    on = Request(rid=1, n_prefill=4, n_decode=4, arrival=0.1, ttft_slo_s=0.5)
    pairs = _pairs(on)
    assert pol.filter_admissions(pairs, 1.0, _FakeEngine([on])) == pairs


def test_policy_cold_start_defers_for_waiting_online():
    pol = SLOAwareOverloadPolicy()
    off = Request(rid=0, n_prefill=4, n_decode=4)
    on = Request(rid=1, n_prefill=4, n_decode=4, arrival=0.1, ttft_slo_s=0.5)
    # online arrived (now=0.2 > 0.1), no TTFT evidence yet -> defer offline
    kept = pol.filter_admissions(_pairs(off), 0.2, _FakeEngine([off, on]))
    assert kept == []
    assert pol.deferrals == 1


def test_policy_relaxes_once_slo_comfortably_met():
    pol = SLOAwareOverloadPolicy()
    pol.record_ttft(0.05, 0.5)             # ratio 0.1, far from headroom
    off = Request(rid=0, n_prefill=4, n_decode=4)
    on = Request(rid=1, n_prefill=4, n_decode=4, arrival=0.1, ttft_slo_s=0.5)
    pairs = _pairs(off)
    # arrived online has waited only 0.1s of a 0.5s budget: no pressure
    assert pol.filter_admissions(pairs, 0.2, _FakeEngine([off, on])) == pairs


def test_policy_attainment_pressure_defers():
    pol = SLOAwareOverloadPolicy()
    pol.record_ttft(0.46, 0.5)             # ratio 0.92 >= headroom 0.85
    off = Request(rid=0, n_prefill=4, n_decode=4)
    on = Request(rid=1, n_prefill=4, n_decode=4, arrival=5.0, ttft_slo_s=0.5)
    kept = pol.filter_admissions(_pairs(off), 1.0, _FakeEngine([off, on]))
    assert kept == []


def test_policy_queue_pressure_defers_on_long_wait():
    pol = SLOAwareOverloadPolicy()
    pol.record_ttft(0.05, 0.5)             # healthy history
    off = Request(rid=0, n_prefill=4, n_decode=4)
    on = Request(rid=1, n_prefill=4, n_decode=4, arrival=0.1, ttft_slo_s=0.5)
    # waited 0.45s of a 0.5s budget >= headroom 0.85
    kept = pol.filter_admissions(_pairs(off), 0.55, _FakeEngine([off, on]))
    assert kept == []


def test_policy_stands_down_when_no_online_remains():
    pol = SLOAwareOverloadPolicy()
    pol.record_ttft(0.49, 0.5)             # attainment pressure on record
    off = Request(rid=0, n_prefill=4, n_decode=4)
    pairs = _pairs(off)
    # queue holds only offline work: nothing left to protect, admit freely
    assert pol.filter_admissions(pairs, 9.0, _FakeEngine([off])) == pairs
    assert pol.deferrals == 0


def test_base_policy_is_identity():
    pol = OverloadPolicy()
    off = Request(rid=0, n_prefill=4, n_decode=4)
    pairs = _pairs(off)
    assert pol.filter_admissions(pairs, 0.0, _FakeEngine([off])) is pairs


# --------------------------------------------------------------------------- #
# Fault injection: kill mid-serve, survivors finish exactly once              #
# --------------------------------------------------------------------------- #
def _fleet(model, params, **fc_kw):
    fc_kw.setdefault("n_replicas", 2)
    return Fleet(
        model, params,
        EngineConfig(n_slots=2, max_len=64, prefill_seq_buckets=(32,),
                     kv_layout="paged", page_size=16, prefill_chunk=16),
        FleetConfig(**fc_kw), cost_model=CM,
    )


def _fault_reqs():
    return [
        Request(rid=i, n_prefill=10, n_decode=12 + 6 * (i % 2))
        for i in range(8)
    ]


def test_replica_kill_recovers_exactly_once(model_and_params):
    model, params = model_and_params
    base = _fleet(model, params)
    base.serve(_fault_reqs(), LagrangianPolicy)                # warm
    for eng in base.engines:
        eng.warm_serving_shapes()
    ref = base.serve(_fault_reqs(), LagrangianPolicy)
    ref_gen = {rid: list(t) for rid, t in base.generated.items()}

    fl = _fleet(model, params)
    fl.serve(_fault_reqs(), LagrangianPolicy)                  # warm
    for eng in fl.engines:
        eng.warm_serving_shapes()
    report = fl.serve(
        _fault_reqs(), LagrangianPolicy,
        fault_plan=FaultPlan([ReplicaFault(replica=0,
                                           at_s=0.25 * ref.makespan)]),
    )
    report.validate()
    done = [r for t in report.traces for r in t.requests]
    assert len(done) == 8 and all(r.t_done is not None for r in done)
    assert len({r.rid for r in done}) == 8                     # exactly once
    assert fl.recovered_requests > 0
    assert fl.generated.keys() == ref_gen.keys()
    for rid, toks in ref_gen.items():
        assert fl.generated[rid] == toks, f"stream diverged for rid {rid}"
    assert report.meta.get("dead_replicas") == 1.0


def test_slow_fault_stretches_replica_not_correctness(model_and_params):
    model, params = model_and_params
    fl = _fleet(model, params)
    fl.serve(_fault_reqs(), LagrangianPolicy)                  # warm
    report = fl.serve(
        _fault_reqs(), LagrangianPolicy,
        fault_plan=FaultPlan([ReplicaFault(replica=1, at_s=0.0, kind="slow",
                                           speed_factor=0.5)]),
    )
    report.validate()
    done = [r for t in report.traces for r in t.requests]
    assert len(done) == 8 and all(r.t_done is not None for r in done)
    assert fl.engines[1].speed_factor == pytest.approx(0.5)


def test_fault_plan_validation():
    with pytest.raises(ValueError):
        ReplicaFault(replica=0, at_s=-1.0)
    with pytest.raises(ValueError):
        ReplicaFault(replica=0, at_s=0.0, kind="explode")
    plan = FaultPlan([ReplicaFault(replica=1, at_s=2.0),
                      ReplicaFault(replica=0, at_s=1.0)])
    assert [f.replica for f in plan.faults] == [0, 1]          # time-sorted
