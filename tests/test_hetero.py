"""Heterogeneous fleet scheduling: the R||Cmax offline solver and lower
bounds (``core.hetero``), per-replica cost models / speed factors in the
fleet, speed-aware dispatch and work stealing, and checkpointing of
per-replica profiler state."""
import jax
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core import (
    CostModel,
    FleetReport,
    GlobalQueueScheduler,
    LagrangianPolicy,
    ReplicaSpec,
    Request,
    ScheduleTrace,
    StageKind,
    StageRecord,
    build_clients,
    evaluate_hetero_assignment,
    hetero_lp_lower_bound,
    hetero_theoretical_lower_bound,
    hetero_weights,
    round_robin_assign,
    solve_hetero,
    solve_offline,
    theoretical_lower_bound,
)
from repro.models.layers import init_params
from repro.models.transformer import TransformerLM
from repro.serving.engine import Engine, EngineConfig
from repro.serving.fleet import Fleet, FleetConfig
from repro.serving.profiler import OnlineProfiler

CFG = ArchConfig(
    name="demo", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=256,
)
CM = CostModel(level_caps=(32, 64, 128))
ENGINE_CFG = dict(
    n_slots=2, max_len=64, prefill_seq_buckets=(32,),
    kv_layout="paged", page_size=16, prefill_chunk=16,
)


@pytest.fixture(scope="module")
def model_and_params():
    model = TransformerLM(CFG)
    params = init_params(jax.random.key(0), model.param_defs())
    return model, params


def _requests(n=12, seed=0):
    rng = np.random.default_rng(seed)
    return [
        Request(
            rid=i,
            n_prefill=8 + int(rng.integers(0, 12)),
            n_decode=4 + int(rng.integers(0, 28)),
        )
        for i in range(n)
    ]


# --------------------------------------------------------------------------- #
# Cost-model scaling                                                          #
# --------------------------------------------------------------------------- #
def test_scaled_cost_model_halves_durations():
    cm = CostModel(level_caps=(64, 128))
    fast = cm.scaled(2.0)
    assert fast.prefill_time(100) == pytest.approx(cm.prefill_time(100) / 2)
    assert fast.decode_round_time(8) == pytest.approx(
        cm.decode_round_time(8) / 2
    )
    assert fast.decode_dispatch == pytest.approx(cm.decode_dispatch / 2)
    # token capacities are not times and must not scale
    assert fast.level_caps == cm.level_caps
    assert cm.scaled(1.0) == cm
    with pytest.raises(ValueError):
        cm.scaled(0.0)


def test_replica_spec_resolves_prior():
    base = CostModel(level_caps=(64, 128))
    assert ReplicaSpec(speed_factor=0.5).resolve_cost_model(base) == base.scaled(0.5)
    explicit = CostModel(decode_overhead=1.23, level_caps=(64, 128))
    assert (
        ReplicaSpec(speed_factor=0.5, cost_model=explicit).resolve_cost_model(base)
        is explicit
    )
    with pytest.raises(ValueError):
        ReplicaSpec(speed_factor=-1.0)


# --------------------------------------------------------------------------- #
# Lower bounds                                                                #
# --------------------------------------------------------------------------- #
def test_hetero_wallclock_bound_reduces_exactly_to_p_cmax():
    """Equal speed factors ⇒ the R||Cmax fleet floor IS the paper's
    P||Cmax bound at n_clients = replicas × slots, bit-for-bit."""
    reqs = _requests(20)
    for n_rep, slots in ((2, 4), (3, 2), (1, 8)):
        cms = [CM.scaled(1.0) for _ in range(n_rep)]
        het = hetero_theoretical_lower_bound(reqs, cms, slots)
        hom = theoretical_lower_bound(reqs, n_rep * slots, CM)
        assert het.total == hom.total
        assert het.t_prefill_star == hom.t_prefill_star
        assert het.t_decode_star == hom.t_decode_star
    # ... and at a uniformly-scaled speed, to the bound of the scaled model
    cms = [CM.scaled(0.5), CM.scaled(0.5)]
    het = hetero_theoretical_lower_bound(reqs, cms, 4)
    assert het.total == theoretical_lower_bound(reqs, 8, CM.scaled(0.5)).total


def test_hetero_wallclock_bound_between_speeds():
    """A mixed-speed fleet's floor sits strictly between the all-fast and
    all-slow homogeneous floors, and never above any achieved-assignment
    makespan estimate."""
    reqs = _requests(20)
    fast, slow = CM.scaled(1.0), CM.scaled(0.5)
    mixed = hetero_theoretical_lower_bound(reqs, [fast, slow], 4).total
    all_fast = hetero_theoretical_lower_bound(reqs, [fast, fast], 4).total
    all_slow = hetero_theoretical_lower_bound(reqs, [slow, slow], 4).total
    assert all_fast < mixed < all_slow


def test_hetero_lp_bound_reduces_to_p_cmax_form():
    """Identical columns ⇒ max(mean per-client load, max item) over the
    flat pool of R·slots clients — the P||Cmax LP-bound form."""
    reqs = _requests(15)
    w = hetero_weights(reqs, [CM, CM], 4)
    col = w[:, 0]
    assert hetero_lp_lower_bound(w, slots=4) == pytest.approx(
        max(float(col.max()), float(col.sum()) / 8)
    )
    assert hetero_lp_lower_bound(np.zeros((0, 2)), slots=4) == 0.0


def test_hetero_lp_bound_floors_every_assignment():
    reqs = _requests(16)
    cms = [CM.scaled(1.0), CM.scaled(0.4)]
    w = hetero_weights(reqs, cms, 4)
    lb = hetero_lp_lower_bound(w, slots=4)
    het = solve_hetero(reqs, cms, 4)
    rr = evaluate_hetero_assignment(
        reqs, round_robin_assign(reqs, 2), cms, 4, solver="rr"
    )
    blind = evaluate_hetero_assignment(
        reqs, solve_offline(reqs, 2, CM).assignment, cms, 4, solver="blind"
    )
    for result in (het, rr, blind):
        assert lb <= result.makespan_est + 1e-9
    assert het.lp_lower_bound == pytest.approx(lb)


# --------------------------------------------------------------------------- #
# R||Cmax solver                                                              #
# --------------------------------------------------------------------------- #
def test_solve_hetero_beats_speed_blind_on_two_speed_fleet():
    reqs = _requests(20)
    cms = [CM.scaled(1.0), CM.scaled(0.5)]
    het = solve_hetero(reqs, cms, 4)
    blind = evaluate_hetero_assignment(
        reqs, solve_offline(reqs, 2, CM).assignment, cms, 4, solver="blind"
    )
    rr = evaluate_hetero_assignment(
        reqs, round_robin_assign(reqs, 2), cms, 4, solver="rr"
    )
    assert het.makespan_est < blind.makespan_est
    assert het.makespan_est < rr.makespan_est
    # the fast replica carries the larger share of the backlog
    assert len(het.assignment[0]) > len(het.assignment[1])
    # all requests assigned exactly once
    assigned = sorted(rid for part in het.assignment for rid in part)
    assert assigned == [r.rid for r in reqs]


def test_solve_hetero_homogeneous_matches_p_cmax_quality():
    """On identical replicas the R||Cmax solver is just LPT + local search;
    its makespan estimate must match solve_offline's (same optimum on a
    P||Cmax instance, modulo tie-breaks) up to the LP gap."""
    reqs = _requests(18)
    cms = [CM, CM, CM]
    het = solve_hetero(reqs, cms, 4)
    # solve_offline prices decode-only weights; re-evaluate its partition on
    # the hetero (prefill+decode) matrix so both sides use identical units
    blind = evaluate_hetero_assignment(
        reqs, solve_offline(reqs, 3, CM).assignment, cms, 4, solver="blind"
    )
    assert het.makespan_est == pytest.approx(blind.makespan_est, rel=0.05)


# --------------------------------------------------------------------------- #
# Speed-weighted fleet utilization (satellite: capacity-weighted denominator) #
# --------------------------------------------------------------------------- #
def _trace(busy_until: float, span: float, n_clients: int = 2) -> ScheduleTrace:
    t = ScheduleTrace(num_clients=n_clients, policy_name="synthetic")
    t.stages.append(
        StageRecord(
            kind=StageKind.DECODE, t_start=0.0, t_end=busy_until, bin_index=0,
            busy={c: c for c in range(n_clients)}, tokens=1, rounds=1,
        )
    )
    if span > busy_until:
        # idle tail: a zero-client stage pinning the makespan
        t.stages.append(
            StageRecord(
                kind=StageKind.DECODE, t_start=span, t_end=span, bin_index=0,
                busy={}, tokens=0,
            )
        )
    return t


def test_fleet_utilization_weights_capacity_by_speed():
    # fast replica busy the whole makespan, slow replica fully idle
    traces = [_trace(10.0, 10.0), _trace(0.0, 10.0)]
    hom = FleetReport(
        policy_name="p", n_replicas=2, slots_per_replica=2, traces=traces,
    )
    het = FleetReport(
        policy_name="p", n_replicas=2, slots_per_replica=2, traces=traces,
        speed_factors=[1.0, 0.5],
    )
    # unweighted: half the slot-time busy
    assert hom.utilization == pytest.approx(0.5)
    # weighted: the idle replica only had half the capacity to waste
    assert het.utilization == pytest.approx(1.0 / 1.5)
    assert het.utilization > hom.utilization
    assert het.weighted_capacity_slots == pytest.approx(3.0)
    # both replicas fully busy ⇒ 1.0 under either weighting
    full = [_trace(10.0, 10.0), _trace(10.0, 10.0)]
    assert FleetReport(
        policy_name="p", n_replicas=2, slots_per_replica=2, traces=full,
        speed_factors=[1.0, 0.5],
    ).utilization == pytest.approx(1.0)
    # explicit all-1.0 factors reduce exactly to the unweighted metric
    assert FleetReport(
        policy_name="p", n_replicas=2, slots_per_replica=2, traces=traces,
        speed_factors=[1.0, 1.0],
    ).utilization == hom.utilization


# --------------------------------------------------------------------------- #
# Fleet integration                                                           #
# --------------------------------------------------------------------------- #
def _hetero_fleet(model, params, specs, engine_kw=None, **fc_kw):
    fc_kw.setdefault("n_replicas", len(specs))
    return Fleet(
        model, params, EngineConfig(**ENGINE_CFG, **(engine_kw or {})),
        FleetConfig(**fc_kw), cost_model=CM, replica_specs=specs,
    )


def test_hetero_fleet_partitions_by_speed_and_validates(model_and_params):
    model, params = model_and_params
    specs = [ReplicaSpec(speed_factor=1.0), ReplicaSpec(speed_factor=0.25)]
    fleet = _hetero_fleet(
        model, params, specs, assign="lpt", work_stealing=False,
        engine_kw=dict(decode_horizon=1, mixed_schedule=False),
    )
    assert fleet.heterogeneous
    # 12 equal requests at a 4× speed ratio: enough work that parking a
    # couple on the slow replica strictly improves the span (with only a
    # handful, the solver rightly gives the fast replica everything — a
    # single request's span on the slow replica is already 4× a fast one)
    reqs = [Request(rid=i, n_prefill=10, n_decode=12) for i in range(12)]
    report = fleet.serve(reqs, LagrangianPolicy)
    report.validate()
    assert report.offline_solver == "hetero-lpt+local_search"
    assert report.speed_factors == [1.0, 0.25]
    n_fast = len(report.traces[0].requests)
    n_slow = len(report.traces[1].requests)
    assert n_fast > n_slow > 0
    assert n_fast + n_slow == 12
    assert report.lower_bound_s > 0
    s = report.summary()
    assert s["speed_factors"] == [1.0, 0.25]
    # the slow replica's virtual stage clock runs ~4× slower, so its
    # per-request wall share is visibly longer despite the smaller share
    assert report.traces[1].makespan > 0


def test_homogeneous_fleet_unchanged_solver_and_speed(model_and_params):
    model, params = model_and_params
    fleet = Fleet(
        model, params, EngineConfig(**ENGINE_CFG), FleetConfig(n_replicas=2),
        cost_model=CM,
    )
    assert not fleet.heterogeneous
    assert all(e.speed_factor == 1.0 for e in fleet.engines)
    report = fleet.serve(
        [Request(rid=i, n_prefill=8, n_decode=6) for i in range(4)],
        LagrangianPolicy,
    )
    assert report.offline_solver == "lpt+local_search"
    report.validate()


def test_speed_factor_scales_virtual_makespan(model_and_params):
    """The same workload on a speed-0.5 engine reports ~2× the virtual
    makespan with identical tokens (the emulation contract)."""
    model, params = model_and_params

    def run(speed):
        eng = Engine(
            model, params, EngineConfig(**ENGINE_CFG), speed_factor=speed,
        )
        eng.profiler.cost_model = CM
        reqs = [Request(rid=i, n_prefill=10, n_decode=10) for i in range(4)]
        clients = build_clients(2, reqs, None)
        trace = eng.serve(
            reqs, clients, GlobalQueueScheduler(reqs), LagrangianPolicy()
        )
        return eng.generated, trace.makespan

    # warm both paths once so compile spikes don't land in either run
    run(1.0)
    run(0.5)
    fast_gen, fast_mk = run(1.0)
    slow_gen, slow_mk = run(0.5)
    assert fast_gen == slow_gen
    # exact ×2 up to CPU noise between the two runs — assert a wide band
    assert slow_mk > 1.3 * fast_mk


# --------------------------------------------------------------------------- #
# Satellite: a profiler refit must change the routing decision               #
# --------------------------------------------------------------------------- #
def test_refit_changes_least_load_routing(model_and_params):
    """Regression for dispatch pricing through the construction-time shared
    CostModel: after replica 0's profiler refits to expensive measured
    stages, ``least_load`` must route the next arrival to replica 1 —
    under the old shared-model pricing the decision could never change."""
    model, params = model_and_params
    fleet = Fleet(
        model, params, EngineConfig(**ENGINE_CFG),
        FleetConfig(n_replicas=2, assign="lpt", dispatch="least_load"),
        cost_model=CM,
        profiler_factory=lambda: OnlineProfiler(initial=CM, refit_every=4),
    )
    reqs = [Request(rid=i, n_prefill=8, n_decode=20) for i in range(4)]
    fleet.begin_serve(reqs, LagrangianPolicy)
    # LPT split 2+2: identical queues, identical priors → tie breaks to 0
    late = Request(rid=99, n_prefill=8, n_decode=20, arrival=0.001)
    assert fleet.dispatcher.choose(fleet, late) == 0
    # replica 0 refits to a model ~100× the prior; replica 1 refits to the
    # prior's own timings (both fitted → live pricing engages)
    slow_p = fleet.engines[0].profiler
    slow_p.record_prefill(32, 3.0)
    slow_p.record_prefill(64, 6.0)
    slow_p.record_decode(1, 2.0)
    slow_p.record_decode(2, 3.9)
    fast_p = fleet.engines[1].profiler
    fast_p.record_prefill(32, CM.prefill_time(32))
    fast_p.record_prefill(64, CM.prefill_time(64))
    fast_p.record_decode(1, CM.decode_round_time(1))
    fast_p.record_decode(2, CM.decode_round_time(2))
    assert slow_p.fits >= 1 and fast_p.fits >= 1
    assert fleet.replica_cost_model(0).decode_round_time(2) > \
        fleet.replica_cost_model(1).decode_round_time(2)
    assert fleet.dispatcher.choose(fleet, late) == 1


def test_pricing_gate_holds_priors_until_all_replicas_fit(model_and_params):
    """A half-fitted fleet must NOT mix measured and prior scales: until
    every replica has refit, cross-replica pricing uses the per-replica
    priors (which already encode the speed ratio)."""
    model, params = model_and_params
    specs = [ReplicaSpec(speed_factor=1.0), ReplicaSpec(speed_factor=0.5)]
    fleet = _hetero_fleet(model, params, specs, assign="lpt")
    priors = [s.resolve_cost_model(CM) for s in specs]
    assert fleet.pricing_cost_models() == priors
    # replica 0 alone refits to (cheap) measured timings
    p0 = fleet.engines[0].profiler
    p0.refit_every = 4
    p0.record_prefill(32, 1e-4)
    p0.record_prefill(64, 2e-4)
    p0.record_decode(1, 1e-4)
    p0.record_decode(2, 1.5e-4)
    assert p0.fits >= 1
    # gate: still the priors (mixed scales would starve replica 1)
    assert fleet.pricing_cost_models() == priors
    # once replica 1 fits too, live models engage
    p1 = fleet.engines[1].profiler
    p1.refit_every = 4
    p1.record_prefill(32, 2e-4)
    p1.record_prefill(64, 4e-4)
    p1.record_decode(1, 2e-4)
    p1.record_decode(2, 3e-4)
    assert p1.fits >= 1
    live = fleet.pricing_cost_models()
    assert live[0] is fleet.engines[0].profiler.cost_model
    assert live[1] is fleet.engines[1].profiler.cost_model


def test_mixed_only_refit_does_not_open_pricing_gate(model_and_params):
    """A mixed-constants-only refit leaves the prefill/decode constants at
    the prior — it must NOT count as 'this replica has measured itself'
    for cross-replica pricing (the gate reads ``full_fits``, not
    ``fits``)."""
    model, params = model_and_params
    specs = [ReplicaSpec(speed_factor=1.0), ReplicaSpec(speed_factor=0.5)]
    fleet = _hetero_fleet(model, params, specs, assign="lpt")
    priors = [s.resolve_cost_model(CM) for s in specs]
    for i, eng in enumerate(fleet.engines):
        p = eng.profiler
        p.refit_every = 3
        # mixed samples only: enough variation for fit_mixed_params but
        # nothing for the full prefill/decode fit
        p.record_mixed(1, 16, 0.01 * (i + 1))
        p.record_mixed(2, 16, 0.02 * (i + 1))
        p.record_mixed(2, 32, 0.03 * (i + 1))
        assert p.fits >= 1 and p.full_fits == 0
    # every replica "fit", but only mixed constants — still the priors
    models = fleet.pricing_cost_models()
    for m, prior in zip(models, priors):
        assert m.decode_round_time(2) == prior.decode_round_time(2)
        assert m.prefill_time(32) == prior.prefill_time(32)


# --------------------------------------------------------------------------- #
# Satellite: work stealing under asymmetric speeds                            #
# --------------------------------------------------------------------------- #
def test_fast_replica_steals_from_slow_and_reduces_makespan(model_and_params):
    """Round-robin piles the long requests onto the slow replica; the fast
    one drains, steals, and the fleet makespan strictly improves over the
    no-steal ablation — while the stolen request's tokens stay identical
    to a bare-engine serve."""
    model, params = model_and_params
    specs = [ReplicaSpec(speed_factor=1.0), ReplicaSpec(speed_factor=0.25)]

    def requests():
        # odd rids (→ slow replica under round-robin) are decode-heavy:
        # 3 longs behind 2 slots leaves one queued for the thief
        out = []
        for rid in range(6):
            if rid % 2 == 1:
                out.append(Request(rid=rid, n_prefill=10, n_decode=32))
            else:
                out.append(Request(rid=rid, n_prefill=8, n_decode=4))
        return out

    reports = {}
    for stealing in (True, False):
        fleet = _hetero_fleet(
            model, params, specs, assign="round_robin",
            dispatch="round_robin", work_stealing=stealing,
            engine_kw=dict(decode_horizon=1, mixed_schedule=False),
        )
        fleet.warm_serving_shapes()
        fleet.serve(requests(), LagrangianPolicy)      # warm
        report = fleet.serve(requests(), LagrangianPolicy)
        report.validate()
        reports[stealing] = (report, fleet.generated, fleet)
    steal_report, steal_gen, steal_fleet = reports[True]
    nosteal_report, nosteal_gen, _ = reports[False]
    assert steal_fleet.steal_events >= 1
    # every stolen request moved fast-ward: from the slow donor (1) to the
    # fast thief (0) — the R||Cmax gate prices the reverse move out
    for e in steal_fleet.steal_log:
        assert (e["from"], e["to"]) == (1, 0)
    # the whole point: stealing strictly reduces the fleet makespan (the
    # slow replica's ×4 virtual time dwarfs CPU timer noise)
    assert steal_report.makespan < nosteal_report.makespan
    # placement never changes tokens
    assert steal_gen == nosteal_gen
    eng = Engine(model, params, EngineConfig(**ENGINE_CFG))
    eng.profiler.cost_model = CM
    ref = requests()
    clients = build_clients(2, ref, None)
    eng.serve(ref, clients, GlobalQueueScheduler(ref), LagrangianPolicy())
    assert eng.generated == steal_gen


def test_steal_gate_prices_through_destination_models(model_and_params):
    """The R||Cmax steal gate, in isolation: a fast thief stealing from a
    slow donor improves the victim's priced finish time; the reverse move
    prices itself out — even when the slow replica is the one starving."""
    model, params = model_and_params
    specs = [ReplicaSpec(speed_factor=1.0), ReplicaSpec(speed_factor=0.1)]
    fleet = _hetero_fleet(
        model, params, specs, assign="round_robin", dispatch="round_robin",
    )
    reqs = [Request(rid=i, n_prefill=10, n_decode=16) for i in range(4)]
    fleet.begin_serve(reqs, LagrangianPolicy)
    # nothing has run: both clocks are 0 and no slot is occupied, so the
    # gate reduces to pure weight comparison through each replica's model
    slow_victim = fleet.engines[1]._sv.scheduler.peek_longest()
    assert slow_victim is not None
    assert fleet._steal_improves(0, 1, slow_victim)
    fast_victim = fleet.engines[0]._sv.scheduler.peek_longest()
    assert fast_victim is not None
    assert not fleet._steal_improves(1, 0, fast_victim)


# --------------------------------------------------------------------------- #
# Checkpoint / restore covers per-replica profiler state                      #
# --------------------------------------------------------------------------- #
def test_fleet_checkpoint_restores_profiler_state(model_and_params):
    model, params = model_and_params
    specs = [ReplicaSpec(speed_factor=1.0), ReplicaSpec(speed_factor=0.5)]
    fleet = _hetero_fleet(model, params, specs, assign="lpt")

    def requests():
        return [
            Request(rid=i, n_prefill=10 + 2 * (i % 3), n_decode=8 + 4 * (i % 4))
            for i in range(6)
        ]

    fleet.begin_serve(requests(), LagrangianPolicy)
    steps = 0
    while steps < 8 and fleet.step():
        steps += 1
    # force distinguishable fitted state on each replica before snapshotting
    for i, eng in enumerate(fleet.engines):
        eng.profiler.refit_every = 4
        eng.profiler.record_prefill(32, 0.01 * (i + 1))
        eng.profiler.record_prefill(64, 0.02 * (i + 1))
        eng.profiler.record_decode(1, 0.004 * (i + 1))
        eng.profiler.record_decode(2, 0.007 * (i + 1))
        assert eng.profiler.fits >= 1
    state = jax.tree_util.tree_map(np.asarray, fleet.state_dict())

    fleet2 = _hetero_fleet(model, params, specs, assign="lpt")
    reqs2 = {r.rid: r for r in requests()}
    fleet2.load_state_dict(state, reqs2)
    for eng, eng2 in zip(fleet.engines, fleet2.engines):
        assert eng2.profiler.cost_model == eng.profiler.cost_model
        assert eng2.profiler.prefill_samples == eng.profiler.prefill_samples
        assert eng2.profiler.decode_samples == eng.profiler.decode_samples
        assert eng2.profiler.fits == eng.profiler.fits
    # restored fleet still finishes and streams stay disjoint per request
    while fleet2.step():
        pass
    report2 = fleet2.finish_serve()
    seen = [r.rid for t in report2.traces for r in t.requests]
    assert len(seen) == len(set(seen))


def test_profiler_state_roundtrip_with_mixed_constants():
    p = OnlineProfiler(initial=CostModel(level_caps=(64, 128)))
    p.record_prefill(16, 0.01)
    p.record_decode(2, 0.02, rounds=4)
    p.record_mixed(2, 16, 0.03)
    state = p.state_dict()
    q = OnlineProfiler()
    q.load_state_dict(state)
    assert q.cost_model == p.cost_model
    assert q.cost_model.mixed_overhead is None      # NaN round-trips to None
    assert q.prefill_samples == [(16, 0.01)]
    assert q.decode_samples == [(2, 4, 0.02)]
    assert q.mixed_samples == [(2, 16, 0.03)]
    # fitted mixed constants survive too
    import dataclasses as dc
    p.cost_model = dc.replace(p.cost_model, mixed_overhead=0.005)
    q.load_state_dict(p.state_dict())
    assert q.cost_model.mixed_overhead == pytest.approx(0.005)


def test_replica_specs_length_validated(model_and_params):
    model, params = model_and_params
    with pytest.raises(ValueError):
        Fleet(
            model, params, EngineConfig(**ENGINE_CFG),
            FleetConfig(n_replicas=2), cost_model=CM,
            replica_specs=[ReplicaSpec()],
        )
