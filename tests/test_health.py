"""Failure detection without an oracle: heartbeat/suspicion monitoring unit
tests, hang and gray-degrade chaos served exactly-once through epoch fencing,
zombie wake-up fencing, deadline-aware redispatch, KV checksum bit-flip
rejection, and health/epoch checkpoint round-trips."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core import CostModel, LagrangianPolicy, Request
from repro.models.layers import init_params
from repro.models.transformer import TransformerLM
from repro.serving.engine import Engine, EngineConfig
from repro.serving.fleet import (
    HEALTH_SUSPECT_PENALTY,
    FaultPlan,
    Fleet,
    FleetConfig,
    ReplicaFault,
)
from repro.serving.health import (
    ALIVE,
    CONDEMNED,
    SUSPECT,
    HealthConfig,
    ReplicaHealthMonitor,
)
from repro.serving.kv_slots import PageIntegrityError
from repro.serving.sampler import greedy

CFG = ArchConfig(
    name="demo", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=256,
)
CM = CostModel(level_caps=(32, 64, 128))
ENGINE_CFG = dict(
    n_slots=2, max_len=64, prefill_seq_buckets=(32,),
    kv_layout="paged", page_size=16, prefill_chunk=16,
    decode_horizon=1, mixed_schedule=False,
)


@pytest.fixture(scope="module")
def model_and_params():
    model = TransformerLM(CFG)
    params = init_params(jax.random.key(0), model.param_defs())
    return model, params


def _fleet(model, params, engine_kw=None, health=True, **fc_kw):
    fc_kw.setdefault("n_replicas", 2)
    fc_kw.setdefault("assign", "round_robin")
    fc_kw.setdefault("dispatch", "round_robin")
    fc_kw.setdefault("work_stealing", False)
    if isinstance(health, HealthConfig):
        fc_kw["health"] = health
    elif health and "health" not in fc_kw:
        fc_kw["health"] = HealthConfig()
    return Fleet(
        model, params, EngineConfig(**{**ENGINE_CFG, **(engine_kw or {})}),
        FleetConfig(**fc_kw), cost_model=CM, sampler=greedy,
    )


def _assert_no_leaks(fleet):
    for eng in fleet.engines:
        assert eng.slots.allocator.num_used == 0, "orphaned pages"
        eng.slots.allocator.check_consistency()
        eng.slots.check_block_table_mirror()


def _requests():
    return [
        Request(rid=0, n_prefill=10, n_decode=16),
        Request(rid=1, n_prefill=8, n_decode=16),
        Request(rid=2, n_prefill=12, n_decode=12),
        Request(rid=3, n_prefill=8, n_decode=12),
    ]


def _calib_requests():
    # prefill totals differ from _requests() so the per-replica profilers
    # see >= 2 distinct prefill sizes and can reach their first FULL refit
    # (each replica batches all its offline prompts into one prefill stage)
    return [Request(rid=90 + i, n_prefill=4, n_decode=8) for i in range(4)]


def _serve_fitted_reference(fleet):
    """Warm + calibrate until every replica has a full cost-model fit, then
    serve once more for the fitted reference streams."""
    fleet.serve(_calib_requests(), LagrangianPolicy)
    fleet.serve(_requests(), LagrangianPolicy)
    assert all(e.profiler.full_fits > 0 for e in fleet.engines)
    rep = fleet.serve(_requests(), LagrangianPolicy)
    ref_gen = {rid: list(t) for rid, t in fleet.generated.items()}
    return rep, ref_gen


# --------------------------------------------------------------------------- #
# Monitor unit tests (no model, no fleet)                                     #
# --------------------------------------------------------------------------- #
def _beaten_monitor(cfg=None, cadence=0.01, n=10):
    mon = ReplicaHealthMonitor(2, cfg or HealthConfig())
    for k in range(n):
        mon.beat(0, k * cadence)
        mon.beat(1, k * cadence)
    return mon


def test_silence_escalates_suspect_then_condemned():
    mon = _beaten_monitor()
    t0 = 9 * 0.01
    assert mon.evaluate(t0 + 0.01) == []          # one normal gap: quiet
    assert mon.state(0) == ALIVE
    # silence grows while replica 1 keeps beating: 0 crosses the suspect
    # sigma first, the condemn sigma later — and is returned exactly once
    newly = []
    t = t0
    while not newly and t < t0 + 10.0:
        t += 0.01
        mon.beat(1, t)
        newly = mon.evaluate(t)
    assert newly == [0]
    assert mon.state(0) == CONDEMNED
    assert mon.state(1) == ALIVE
    assert mon.suspect_events == 1 and mon.condemned_events == 1
    # already condemned: never returned again, beats are ignored
    assert mon.evaluate(t + 1.0, replicas=[0]) == []
    mon.beat(0, t + 1.0)
    assert mon.state(0) == CONDEMNED
    states = [tr["state"] for tr in mon.transitions if tr["replica"] == 0]
    assert states == [SUSPECT, CONDEMNED]


def test_condemnation_gated_on_warmup_beats():
    cfg = HealthConfig(warmup_beats=4)
    mon = ReplicaHealthMonitor(1, cfg)
    mon.beat(0, 0.0)                              # 1 beat < warmup
    mon.evaluate(100.0)
    assert mon.state(0) == SUSPECT                # may suspect...
    assert mon.condemned_events == 0              # ...but never condemn


def test_beat_clears_suspicion_and_counts_false_positive():
    mon = _beaten_monitor()
    t = 9 * 0.01
    while mon.state(0) != SUSPECT:
        t += 0.01
        mon.beat(1, t)
        mon.evaluate(t)
    assert mon.state(0) != CONDEMNED
    mon.beat(0, t)                                # it was merely slow
    assert mon.state(0) == ALIVE
    assert mon.false_suspicions == 1
    assert mon.replicas[0].suspect_since is None


def test_fixed_detector_scores_silence_against_timeout():
    cfg = HealthConfig(
        detector="fixed", fixed_timeout_s=0.1, condemn_factor=2.0,
        warmup_beats=1,
    )
    mon = ReplicaHealthMonitor(1, cfg)
    mon.beat(0, 0.0)
    assert mon.suspicion(0, 0.05) == pytest.approx(0.5)
    mon.evaluate(0.05)
    assert mon.state(0) == ALIVE
    mon.evaluate(0.11)                            # silence > timeout
    assert mon.state(0) == SUSPECT
    mon.evaluate(0.21)                            # silence > 2x timeout
    assert mon.state(0) == CONDEMNED


def test_same_instant_beats_do_not_collapse_gap_stats():
    mon = ReplicaHealthMonitor(1, HealthConfig())
    mon.beat(0, 0.0)
    for _ in range(50):
        mon.beat(0, 0.01)                         # idle re-assertions
    assert mon.replicas[0].gaps == [0.01]
    # the learned cadence is still 0.01, so a normal-cadence step later is
    # not suspicious (zero gaps would have shrunk mean+spread toward 0)
    assert mon.evaluate(0.02) == []
    assert mon.state(0) == ALIVE


def test_degraded_flagged_and_recovers():
    cfg = HealthConfig(baseline_beats=4, degraded_window=4)
    mon = ReplicaHealthMonitor(1, cfg)
    t = 0.0
    for _ in range(cfg.baseline_beats):           # healthy baseline ~1.0
        t += 0.01
        mon.beat(0, t, duration_s=0.01, predicted_s=0.01)
    assert mon.replicas[0].slowdown_baseline == pytest.approx(1.0)
    for _ in range(cfg.degraded_window):          # then everything x4
        t += 0.04
        mon.beat(0, t, duration_s=0.04, predicted_s=0.01)
    assert mon.replicas[0].degraded
    assert mon.state(0) == SUSPECT
    assert mon.replicas[0].suspect_reason == "degraded"
    assert mon.degraded_events == 1
    assert not mon.is_healthy(0)
    for _ in range(cfg.degraded_window):          # recovers to x1
        t += 0.01
        mon.beat(0, t, duration_s=0.01, predicted_s=0.01)
    assert not mon.replicas[0].degraded
    assert mon.state(0) == ALIVE
    assert mon.false_suspicions == 1


def test_degraded_needs_full_window_not_one_spike():
    cfg = HealthConfig(baseline_beats=4, degraded_window=4)
    mon = ReplicaHealthMonitor(1, cfg)
    t = 0.0
    for _ in range(cfg.baseline_beats):
        t += 0.01
        mon.beat(0, t, duration_s=0.01, predicted_s=0.01)
    # a single 50x spike (first-hit compile, host jitter) must not flag
    mon.beat(0, t + 0.5, duration_s=0.5, predicted_s=0.01)
    t += 0.5
    for _ in range(3):
        t += 0.01
        mon.beat(0, t, duration_s=0.01, predicted_s=0.01)
    assert not mon.replicas[0].degraded
    assert mon.state(0) == ALIVE


def test_model_version_change_recaptures_baseline():
    cfg = HealthConfig(baseline_beats=4, degraded_window=4)
    mon = ReplicaHealthMonitor(1, cfg)
    t = 0.0
    for _ in range(cfg.baseline_beats):
        t += 0.01
        mon.beat(0, t, duration_s=0.01, predicted_s=0.01, model_version=0)
    assert mon.replicas[0].slowdown_baseline == pytest.approx(1.0)
    # the cost model refit: the same measured durations now price 4x against
    # the new fit — without rebaselining this would be a false degrade flag
    for _ in range(cfg.baseline_beats + cfg.degraded_window):
        t += 0.01
        mon.beat(0, t, duration_s=0.04, predicted_s=0.01, model_version=1)
    assert mon.replicas[0].slowdown_baseline == pytest.approx(4.0)
    assert not mon.replicas[0].degraded
    assert mon.state(0) == ALIVE


def test_health_config_validation():
    with pytest.raises(ValueError):
        HealthConfig(detector="psychic")
    with pytest.raises(ValueError):
        HealthConfig(suspect_sigma=8.0, condemn_sigma=8.0)
    with pytest.raises(ValueError):
        HealthConfig(fixed_timeout_s=0.0)
    with pytest.raises(ValueError):
        HealthConfig(degraded_factor=1.0)


def test_monitor_state_dict_round_trips_suspicion():
    mon = _beaten_monitor()
    t = 9 * 0.01
    while mon.state(0) != SUSPECT:
        t += 0.01
        mon.beat(1, t)
        mon.evaluate(t)
    blob = mon.state_dict()
    mon2 = ReplicaHealthMonitor(2, HealthConfig())
    mon2.load_state_dict(blob)
    assert mon2.state(0) == SUSPECT               # NOT reset to ALIVE
    assert mon2.replicas[0].suspect_since == mon.replicas[0].suspect_since
    assert mon2.replicas[0].gaps == mon.replicas[0].gaps
    assert mon2.suspect_events == mon.suspect_events
    assert mon2.transitions == mon.transitions
    with pytest.raises(ValueError):
        ReplicaHealthMonitor(3, HealthConfig()).load_state_dict(blob)


def test_hang_fault_validation():
    with pytest.raises(ValueError):
        ReplicaFault(replica=0, at_s=1.0, kind="hang")          # no until_s
    with pytest.raises(ValueError):
        ReplicaFault(replica=0, at_s=1.0, kind="hang", until_s=0.5)
    with pytest.raises(ValueError):
        ReplicaFault(replica=0, at_s=1.0, kind="degrade", speed_factor=0.0)


# --------------------------------------------------------------------------- #
# Fencing + dispatch-eligibility units (fleet, no serving steps needed)       #
# --------------------------------------------------------------------------- #
def test_deliver_completion_fences_stale_claims(model_and_params):
    model, params = model_and_params
    fleet = _fleet(model, params)
    fleet.begin_serve(_requests(), LagrangianPolicy)
    rid = 0
    holder, epoch = fleet._leases[rid]
    # stale epoch: the replica was fenced since this claim was minted
    assert not fleet.deliver_completion(holder, epoch + 1, rid, [7], 0.0)
    # lease mismatch: another replica claims a request it never held
    other = 1 - holder
    assert not fleet.deliver_completion(
        other, fleet.epochs[other], rid, [7], 0.0
    )
    assert fleet.fenced_completions == 2
    reasons = [e["reason"] for e in fleet.fenced_log]
    assert any("stale epoch" in r for r in reasons)
    assert any("lease mismatch" in r for r in reasons)
    # the genuine holder under the current epoch is accepted
    assert fleet.deliver_completion(holder, epoch, rid, [7, 8], 0.0)
    assert fleet.engines[holder].generated[rid] == [7, 8]
    # dead replicas are fenced regardless of epoch
    fleet._dead.add(holder)
    assert not fleet.deliver_completion(holder, epoch, rid, [9], 0.0)
    assert fleet.fenced_log[-1]["reason"] == "replica dead"


def test_suspect_replica_priced_out_of_dispatch(model_and_params):
    model, params = model_and_params
    fleet = _fleet(model, params)
    assert fleet.health_penalties() == [1.0, 1.0]
    fleet.monitor._suspect(0, 0.0, "silence")
    assert fleet.dispatchable_replicas == [1]
    assert fleet.health_penalties() == [HEALTH_SUSPECT_PENALTY, 1.0]
    # both suspect: work still has to land somewhere
    fleet.monitor._suspect(1, 0.0, "silence")
    assert fleet.dispatchable_replicas == [0, 1]
    # no monitor: no penalties, everything dispatchable
    bare = _fleet(model, params, health=False)
    assert bare.health_penalties() is None
    assert bare.dispatchable_replicas == [0, 1]


def test_redispatch_waits_backoff_unless_deadline_pressed(model_and_params):
    model, params = model_and_params
    fleet = _fleet(
        model, params,
        health=HealthConfig(redispatch_backoff_s=0.05),
    )
    reqs = _requests()
    reqs[0].ttft_slo_s = 0.01                     # r0's first request: tight
    fleet.begin_serve(reqs, LagrangianPolicy)
    q0 = fleet.engines[0]._sv.scheduler
    n0 = len(q0.queued)
    assert n0 > 0
    fleet.monitor._suspect(0, 0.0, "silence")
    # before the backoff: only the deadline-pressed request moves
    fleet._redispatch_suspect_queues(0.0)
    assert len(q0.queued) == n0 - 1
    assert fleet.redispatch_events == 1
    assert fleet.redispatch_log[0] == {
        "rid": 0, "from": 0, "to": 1, "at_s": 0.0, "deadline": True,
    }
    assert fleet._leases[0] == (1, 0)
    assert reqs[0].redispatches == 1
    # backoff elapsed: the rest of the queue drains to the healthy replica
    fleet._redispatch_suspect_queues(0.06)
    assert len(q0.queued) == 0
    assert all(e["to"] == 1 for e in fleet.redispatch_log)
    assert all(
        fleet._leases[e["rid"]] == (1, 0) for e in fleet.redispatch_log
    )


# --------------------------------------------------------------------------- #
# Tentpole: mid-serve hang detected without an oracle, served exactly once   #
# --------------------------------------------------------------------------- #
def test_hang_detected_condemned_and_served_exactly_once(model_and_params):
    model, params = model_and_params
    fleet = _fleet(model, params)
    rep, ref_gen = _serve_fitted_reference(fleet)
    mk = rep.makespan

    # replica 0 silently stops mid-serve and never resumes within the serve;
    # the fleet is NOT told (injected_log is chaos ground truth, fault_log
    # stays oracle-free for this kind)
    plan = FaultPlan([ReplicaFault(
        replica=0, at_s=0.3 * mk, kind="hang", until_s=50.0 * mk,
    )])
    rep2 = fleet.serve(_requests(), LagrangianPolicy, fault_plan=plan)

    assert fleet.monitor.state(0) == CONDEMNED
    assert rep2.meta["condemned_replicas"] == 1.0
    assert fleet.epochs[0] == 1                   # fenced before evacuation
    # the ghost (flushed at finish_serve) replayed its stale claims and the
    # fence discarded every one
    assert rep2.meta["fenced_stale_completions"] > 0
    assert all(e["epoch"] == 0 for e in fleet.fenced_log)
    # exactly-once: every request served, streams bit-identical to the
    # no-fault serve (the Fleet.generated merge raises on any double-serve)
    assert {r: list(t) for r, t in fleet.generated.items()} == ref_gen
    # detection latency is bounded: condemned within the serve, well before
    # the hang would have resumed
    condemned_at = next(
        tr["at_s"] for tr in fleet.monitor.transitions
        if tr["state"] == CONDEMNED
    )
    assert 0.3 * mk < condemned_at < 10.0 * mk
    _assert_no_leaks(fleet)


def test_zombie_wakeup_after_condemnation_is_fenced(model_and_params):
    model, params = model_and_params
    fleet = _fleet(model, params)
    rep, ref_gen = _serve_fitted_reference(fleet)
    mk = rep.makespan

    # the hang RESUMES before the serve ends: the condemned replica wakes as
    # a zombie and replays the in-flight work it held — every delivery must
    # hit the fence, none may land in a second replica's output
    plan = FaultPlan([ReplicaFault(
        replica=0, at_s=0.3 * mk, kind="hang", until_s=0.9 * mk,
    )])
    rep2 = fleet.serve(_requests(), LagrangianPolicy, fault_plan=plan)

    assert rep2.meta["condemned_replicas"] == 1.0
    assert rep2.meta["fenced_stale_completions"] > 0
    kinds = [e["kind"] for e in fleet.injected_log]
    assert kinds.count("hang") == 1 and kinds.count("hang_end") == 1
    # zero double-served tokens: bit-identical streams, one claim per rid
    assert {r: list(t) for r, t in fleet.generated.items()} == ref_gen
    fenced_rids = {e["rid"] for e in fleet.fenced_log}
    assert fenced_rids                            # the ghost really replayed
    _assert_no_leaks(fleet)


def test_degrade_x4_flagged_while_progressing(model_and_params):
    model, params = model_and_params
    fleet = _fleet(model, params)
    rep, ref_gen = _serve_fitted_reference(fleet)
    mk = rep.makespan

    # x4-slow gray failure (speed_factor scales virtual time: 0.25 = x4
    # duration), applied mid-serve, fleet not told
    plan = FaultPlan([ReplicaFault(
        replica=0, at_s=0.3 * mk, kind="degrade", speed_factor=0.25,
    )])
    rep2 = fleet.serve(_requests(), LagrangianPolicy, fault_plan=plan)

    assert rep2.meta["degraded_events"] >= 1.0
    assert fleet.monitor.replicas[0].suspect_reason == "degraded"
    assert fleet.monitor.state(0) == SUSPECT      # flagged, NOT condemned
    assert rep2.meta["condemned_replicas"] == 0.0
    # the degraded replica kept progressing: streams still bit-identical
    assert {r: list(t) for r, t in fleet.generated.items()} == ref_gen
    # the survivor was never flagged
    assert fleet.monitor.replicas[1].state == ALIVE
    assert rep2.meta["false_suspicions"] == 0.0
    _assert_no_leaks(fleet)


def test_clean_serve_has_no_false_positives(model_and_params):
    model, params = model_and_params
    fleet = _fleet(model, params)
    rep, _ = _serve_fitted_reference(fleet)
    assert rep.meta["suspect_events"] == 0.0
    assert rep.meta["false_suspicions"] == 0.0
    assert rep.meta["degraded_events"] == 0.0
    assert rep.meta["condemned_replicas"] == 0.0
    assert "fenced_stale_completions" not in rep.meta


# --------------------------------------------------------------------------- #
# Satellite: KV page-integrity checksums reject a bit-flipped migration       #
# --------------------------------------------------------------------------- #
def _run_until_bound_slot(fleet, replica):
    """Step until ``replica`` has a decode-bound slot; return the slot."""
    while fleet.step():
        eng = fleet.engines[replica]
        for slot in list(eng.slots.active_slots):
            if eng.slots.emitted[slot] >= 2:
                return slot
    raise AssertionError("no bound slot materialized")


def test_bitflip_checksum_rejected_then_recompute_fallback(model_and_params):
    model, params = model_and_params

    def requests():
        # three requests: replica 1 keeps a free slot to import into
        return _requests()[:3]

    base = _fleet(model, params)
    base.serve(requests(), LagrangianPolicy)      # warm
    base.serve(requests(), LagrangianPolicy)
    ref_gen = {rid: list(t) for rid, t in base.generated.items()}

    # engine-level: a flipped payload bit fails the CRC at import, with the
    # destination pool untouched
    fleet = _fleet(model, params)
    fleet.begin_serve(requests(), LagrangianPolicy)
    slot = _run_until_bound_slot(fleet, 0)
    ckpt = fleet.engines[0].export_slot(slot)
    k = np.ascontiguousarray(np.asarray(ckpt.k_pages)).copy()
    k.view(np.uint8).flat[0] ^= 1                 # literally one bit
    corrupt = dataclasses.replace(ckpt, k_pages=k)
    dst = fleet.engines[1]
    used_before = dst.slots.allocator.num_used
    with pytest.raises(PageIntegrityError):
        dst.import_slot(corrupt)
    assert dst.slots.allocator.num_used == used_before
    dst.slots.allocator.check_consistency()
    # the UNcorrupted checkpoint still imports cleanly afterwards
    dst.import_slot(ckpt)
    while fleet.step():
        pass
    fleet.finish_serve()

    # fleet-level: migrate_slot falls back to recompute-on-resume when the
    # payload is corrupted in flight, and the stream stays bit-identical
    fleet2 = _fleet(model, params)
    fleet2.begin_serve(requests(), LagrangianPolicy)
    slot = _run_until_bound_slot(fleet2, 0)
    orig_import = Engine.import_slot

    def corrupting_import(self, ckpt):
        flipped = np.ascontiguousarray(np.asarray(ckpt.k_pages)).copy()
        flipped.view(np.uint8).flat[0] ^= 1
        return orig_import(self, dataclasses.replace(ckpt, k_pages=flipped))

    Engine.import_slot = corrupting_import
    try:
        res = fleet2.migrate_slot(0, slot, 1)
    finally:
        Engine.import_slot = orig_import
    assert res == "recompute"
    assert fleet2.integrity_rejections == 1
    assert fleet2.migration_log[-1]["integrity_rejected"] == 1
    while fleet2.step():
        pass
    rep = fleet2.finish_serve()
    assert rep.meta["integrity_rejections"] == 1.0
    assert {r: list(t) for r, t in fleet2.generated.items()} == ref_gen
    _assert_no_leaks(fleet2)


def test_stale_epoch_export_refused(model_and_params):
    model, params = model_and_params
    fleet = _fleet(model, params)
    fleet.begin_serve(_requests(), LagrangianPolicy)
    slot = _run_until_bound_slot(fleet, 0)
    # an exporter fenced mid-flight: its epoch-stamped export is discarded
    # before any pages move
    assert fleet.migrate_slot(0, slot, 1, src_epoch=fleet.epochs[0] - 1) \
        is False
    assert fleet.fenced_exports == 1
    assert fleet.fenced_log[-1]["kind"] == "export"
    # the slot is still live on the source and the serve completes
    assert fleet.engines[0].slots.request_of[slot] is not None
    while fleet.step():
        pass
    fleet.finish_serve()
    _assert_no_leaks(fleet)


# --------------------------------------------------------------------------- #
# Satellite: fleet checkpoints round-trip health + epoch state                #
# --------------------------------------------------------------------------- #
def test_fleet_checkpoint_round_trips_health_and_epochs(model_and_params):
    model, params = model_and_params
    fleet = _fleet(model, params)
    fleet.begin_serve(_requests(), LagrangianPolicy)
    for _ in range(4):
        fleet.step()
    now = max(e.clock for e in fleet.engines)
    # a live suspicion + one fenced claim, then checkpoint mid-serve
    fleet.monitor._suspect(0, now, "silence")
    assert not fleet.deliver_completion(1, 99, 1, [5], now)
    state = jax.tree_util.tree_map(np.asarray, fleet.state_dict())
    pre = {rid: list(t) for rid, t in fleet.generated.items()}

    fleet2 = _fleet(model, params)
    fleet2.load_state_dict(state, {r.rid: r for r in _requests()})
    # the regression: a restored fleet must NOT wake the suspect up ALIVE
    assert fleet2.monitor.state(0) == SUSPECT
    assert fleet2.monitor.replicas[0].suspect_since == pytest.approx(now)
    assert fleet2.epochs == fleet.epochs
    assert fleet2.fenced_completions == 1
    assert fleet2.fenced_log == fleet.fenced_log
    assert fleet2._leases == fleet._leases
    while fleet2.step():
        pass
    fleet2.finish_serve()
    post = fleet2.generated
    served = {
        rid for rid in range(4) if pre.get(rid) or post.get(rid)
    }
    assert served == {0, 1, 2, 3}
    _assert_no_leaks(fleet2)

    # restoring health state into a fleet built WITHOUT a monitor must fail
    # loudly, not silently drop the suspicion
    bare = _fleet(model, params, health=False)
    with pytest.raises(ValueError):
        bare.load_state_dict(state, {r.rid: r for r in _requests()})


# --------------------------------------------------------------------------- #
# Satellite: fault-timing boundaries                                          #
# --------------------------------------------------------------------------- #
def test_fault_at_exactly_current_clock_fires(model_and_params):
    model, params = model_and_params
    fleet = _fleet(model, params)
    fleet.serve(_requests(), LagrangianPolicy)    # warm
    fleet.serve(_requests(), LagrangianPolicy)
    ref_gen = {rid: list(t) for rid, t in fleet.generated.items()}
    # at_s == the fleet clock at serve start (0.0): due on the very first
    # step, not skipped by an open-interval comparison
    plan = FaultPlan([ReplicaFault(
        replica=0, at_s=0.0, kind="hang", until_s=1e-6,
    )])
    fleet.serve(_requests(), LagrangianPolicy, fault_plan=plan)
    kinds = [e["kind"] for e in fleet.injected_log]
    assert kinds == ["hang", "hang_end"]
    assert fleet.injected_log[0]["applied_at_s"] == 0.0
    # the blip resumed before detection: nothing condemned, streams intact
    assert fleet.monitor.condemned_events == 0
    assert {r: list(t) for r, t in fleet.generated.items()} == ref_gen


def test_two_faults_same_instant_apply_in_stable_order(model_and_params):
    model, params = model_and_params
    fleet = _fleet(model, params)
    fleet.serve(_requests(), LagrangianPolicy)    # warm
    # two degrades on the same replica at the same instant: applied in plan
    # order (FaultPlan's sort is stable on the (at_s, replica) tie)
    plan = FaultPlan([
        ReplicaFault(replica=0, at_s=0.0, kind="degrade", speed_factor=0.5),
        ReplicaFault(replica=0, at_s=0.0, kind="degrade", speed_factor=0.25),
    ])
    fleet.serve(_requests(), LagrangianPolicy, fault_plan=plan)
    degrades = [e for e in fleet.injected_log if e["kind"] == "degrade"]
    assert [e["speed_factor"] for e in degrades] == [0.5, 0.125]
    assert fleet.engines[0].speed_factor == pytest.approx(0.125)
    # and across replicas the tie breaks by replica index
    plan2 = FaultPlan([
        ReplicaFault(replica=1, at_s=0.5, kind="degrade", speed_factor=0.5),
        ReplicaFault(replica=0, at_s=0.5, kind="degrade", speed_factor=0.5),
    ])
    assert [f.replica for f in plan2.faults] == [0, 1]
