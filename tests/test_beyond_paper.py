"""Beyond-paper policy guarantees: the balanced rule never loses to the
paper's rule, and fixes its starvation mode; amortized beats the paper's
rule on its own benchmark."""
import dataclasses

import pytest

from repro.core import (
    PAPER_COST_MODEL,
    AmortizedPolicy,
    BalancedLagrangianPolicy,
    LagrangianPolicy,
    PrefillFirstPolicy,
    simulate,
)
from repro.data import (
    PAPER_PREDICTOR_NOISE_STD,
    PAPER_WORKLOAD_SPEC,
    gsm8k_like_workload,
)


def _run(spec, pol, seed=0):
    reqs = gsm8k_like_workload(spec, seed=seed,
                               estimate_noise_std=PAPER_PREDICTOR_NOISE_STD)
    return simulate(reqs, 200, PAPER_COST_MODEL, mode="hybrid",
                    iteration_policy=pol)


def test_balanced_equals_paper_on_gsm8k():
    a = _run(PAPER_WORKLOAD_SPEC, LagrangianPolicy())
    b = _run(PAPER_WORKLOAD_SPEC, BalancedLagrangianPolicy())
    # saturation guard dormant on decode-heavy workloads
    assert abs(a.makespan - b.makespan) < 0.5
    assert abs(a.utilization - b.utilization) < 0.005


@pytest.mark.slow
def test_balanced_fixes_long_prompt_starvation():
    spec = dataclasses.replace(PAPER_WORKLOAD_SPEC, input_mean=400.0, input_std=120.0)
    paper = _run(spec, LagrangianPolicy())
    ours = _run(spec, BalancedLagrangianPolicy())
    base = _run(spec, PrefillFirstPolicy())
    assert paper.utilization < base.utilization - 0.15   # the failure mode
    assert ours.utilization > base.utilization           # fixed, and better
    assert ours.makespan < paper.makespan * 0.70


def test_amortized_beats_paper_on_its_own_benchmark():
    paper = _run(PAPER_WORKLOAD_SPEC, LagrangianPolicy())
    ours = _run(PAPER_WORKLOAD_SPEC, AmortizedPolicy())
    assert ours.utilization > paper.utilization
    assert ours.makespan < paper.makespan
