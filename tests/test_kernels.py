"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp oracles
(interpret mode on CPU; same kernels compile for TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import (
    decode_attention,
    flash_attention,
    paged_decode_attention,
    rglru_scan,
)
from repro.kernels.ref import (
    decode_attention_ref,
    flash_attention_ref,
    paged_decode_attention_ref,
    rglru_scan_ref,
)

FLASH_CASES = [
    # (B, H, KV, S, D, causal, window, dtype)
    (2, 4, 2, 256, 64, True, 0, jnp.float32),
    (1, 4, 4, 128, 128, True, 32, jnp.float32),
    (2, 2, 1, 256, 64, False, 0, jnp.float32),
    (1, 8, 2, 128, 64, True, 0, jnp.bfloat16),
    (1, 2, 2, 64, 32, True, 16, jnp.bfloat16),
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_matches_oracle(case):
    b, h, kv, s, d, causal, w, dtype = case
    ks = jax.random.split(jax.random.key(hash(case) % 2**31), 3)
    q = jax.random.normal(ks[0], (b, h, s, d), dtype)
    k = jax.random.normal(ks[1], (b, kv, s, d), dtype)
    v = jax.random.normal(ks[2], (b, kv, s, d), dtype)
    out = flash_attention(q, k, v, causal=causal, window=w, block_q=64, block_k=64)
    ref = flash_attention_ref(q, k, v, causal=causal, window=w)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol
    )


DECODE_CASES = [
    (3, 8, 2, 512, 64, jnp.float32),
    (2, 4, 4, 256, 128, jnp.float32),
    (2, 8, 1, 128, 64, jnp.bfloat16),
]


@pytest.mark.parametrize("case", DECODE_CASES)
def test_decode_attention_matches_oracle(case):
    b, h, kv, s, d, dtype = case
    ks = jax.random.split(jax.random.key(hash(case) % 2**31), 4)
    q = jax.random.normal(ks[0], (b, h, d), dtype)
    k = jax.random.normal(ks[1], (b, kv, s, d), dtype)
    v = jax.random.normal(ks[2], (b, kv, s, d), dtype)
    lengths = jax.random.randint(ks[3], (b,), 1, s + 1)
    out = decode_attention(q, k, v, lengths, block_k=128)
    ref = decode_attention_ref(q, k, v, lengths)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol
    )


def test_decode_attention_ragged_lengths_mask_garbage():
    """Cache rows beyond each slot's length must not affect the output."""
    b, h, kv, s, d = 2, 4, 2, 256, 64
    ks = jax.random.split(jax.random.key(0), 4)
    q = jax.random.normal(ks[0], (b, h, d))
    k = jax.random.normal(ks[1], (b, kv, s, d))
    v = jax.random.normal(ks[2], (b, kv, s, d))
    lengths = jnp.array([100, 17], jnp.int32)
    out1 = decode_attention(q, k, v, lengths, block_k=64)
    # poison the invalid region
    poison = jnp.where(
        jnp.arange(s)[None, None, :, None] >= lengths[:, None, None, None],
        1e9, 0.0,
    )
    out2 = decode_attention(q, k + poison, v + poison, lengths, block_k=64)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-5, atol=1e-5)


def test_decode_attention_non_divisible_cache_len():
    """Cache lengths that don't divide block_k round the grid up and mask the
    tail block (the old code raised — with an inverted message at that)."""
    b, h, kv, s, d = 2, 4, 2, 200, 64
    ks = jax.random.split(jax.random.key(3), 4)
    q = jax.random.normal(ks[0], (b, h, d))
    k = jax.random.normal(ks[1], (b, kv, s, d))
    v = jax.random.normal(ks[2], (b, kv, s, d))
    lengths = jnp.array([200, 37], jnp.int32)
    out = decode_attention(q, k, v, lengths, block_k=64)
    ref = decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


PAGED_CASES = [
    # (B, H, KV, pages, page_size, MB, D, dtype)
    (3, 8, 2, 24, 16, 8, 64, jnp.float32),
    (2, 4, 4, 12, 8, 6, 128, jnp.float32),
    (2, 8, 1, 10, 32, 4, 64, jnp.bfloat16),
]


@pytest.mark.parametrize("case", PAGED_CASES)
def test_paged_decode_attention_matches_oracle(case):
    b, h, kv, p, bs, mb, d, dtype = case
    # seed from the int fields only: hash() of a dtype object is id-based
    # and would make inputs differ across pytest processes
    ks = jax.random.split(jax.random.key(sum(case[:-1])), 4)
    q = jax.random.normal(ks[0], (b, h, d), dtype)
    k_pages = jax.random.normal(ks[1], (kv, p, bs, d), dtype)
    v_pages = jax.random.normal(ks[2], (kv, p, bs, d), dtype)
    # shuffled physical pages: the kernel must follow the table, not the pool
    rng = np.random.default_rng(sum(case[:-1]))
    perm = rng.permutation(p)
    lengths = rng.integers(1, mb * bs + 1, size=b)
    tables = np.full((b, mb), -1, np.int32)
    used = 0
    for i in range(b):
        need = -(-int(lengths[i]) // bs)
        assert used + need <= p, "case under-provisions pages"
        tables[i, :need] = perm[used : used + need]
        used += need
    out = paged_decode_attention(
        q, k_pages, v_pages, jnp.asarray(tables), jnp.asarray(lengths)
    )
    ref = paged_decode_attention_ref(
        q, k_pages, v_pages, jnp.asarray(tables), jnp.asarray(lengths)
    )
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol
    )


def test_paged_decode_attention_ignores_unallocated_pages():
    """Poisoning pages no table points at must not change any output."""
    b, h, kv, p, bs, mb, d = 2, 4, 2, 10, 16, 4, 64
    ks = jax.random.split(jax.random.key(9), 4)
    q = jax.random.normal(ks[0], (b, h, d))
    k_pages = jax.random.normal(ks[1], (kv, p, bs, d))
    v_pages = jax.random.normal(ks[2], (kv, p, bs, d))
    tables = jnp.asarray([[4, 2, -1, -1], [7, -1, -1, -1]], jnp.int32)
    lengths = jnp.asarray([30, 9], jnp.int32)
    out1 = paged_decode_attention(q, k_pages, v_pages, tables, lengths)
    owned = {4, 2, 7}
    poison = jnp.asarray(
        [[1e9 if i not in owned else 0.0] for i in range(p)]
    ).reshape(1, p, 1, 1)
    out2 = paged_decode_attention(
        q, k_pages + poison, v_pages + poison, tables, lengths
    )
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-5, atol=1e-5)


RGLRU_CASES = [(2, 512, 256), (1, 256, 128), (3, 128, 384)]


@pytest.mark.parametrize("case", RGLRU_CASES)
def test_rglru_matches_oracle(case):
    b, s, r = case
    ks = jax.random.split(jax.random.key(sum(case)), 3)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (b, s, r)))
    x = jax.random.normal(ks[1], (b, s, r))
    h0 = jax.random.normal(ks[2], (b, r))
    out, hf = rglru_scan(a, x, h0, block_s=128, block_r=128)
    rout, rhf = rglru_scan_ref(a, x, h0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(rout), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(rhf), rtol=1e-5, atol=1e-5)


def test_rglru_state_chains_across_calls():
    """Final state of one call seeds the next (decode contract)."""
    b, s, r = 1, 128, 128
    ks = jax.random.split(jax.random.key(7), 2)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (b, 2 * s, r)))
    x = jax.random.normal(ks[1], (b, 2 * s, r))
    full, hf_full = rglru_scan(a, x)
    h1, hf1 = rglru_scan(a[:, :s], x[:, :s])
    h2, hf2 = rglru_scan(a[:, s:], x[:, s:], hf1)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(full[:, s:]), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(hf2), np.asarray(hf_full), rtol=1e-5, atol=1e-5)


def test_model_attention_chunked_banded_equivalence():
    """The model-side chunked/banded paths equal dense attention (these are
    the functions the dry-run lowers)."""
    from repro.models.attention import attention, banded_attention, chunked_attention

    B, S, H, KV, D = 2, 64, 4, 2, 16
    q = jax.random.normal(jax.random.key(1), (B, S, H, D))
    k = jax.random.normal(jax.random.key(2), (B, S, KV, D))
    v = jax.random.normal(jax.random.key(3), (B, S, KV, D))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)
    for causal in (True, False):
        for w in (0, 8):
            ref = attention(q, k, v, q_positions=pos, k_positions=pos, causal=causal, window=w)
            ch = chunked_attention(
                q, k, v, q_positions=pos, k_positions=pos, causal=causal,
                window=w, q_chunk=16, k_chunk=16,
            )
            np.testing.assert_allclose(np.asarray(ch), np.asarray(ref), rtol=1e-5, atol=1e-5)
    ref = attention(q, k, v, q_positions=pos, k_positions=pos, causal=True, window=8)
    bd = banded_attention(
        q, k, v, q_positions=pos, k_positions=pos, window=8, causal=True, q_chunk=16
    )
    np.testing.assert_allclose(np.asarray(bd), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_chunked_attention_grad_matches_dense():
    """The q-block remat must not change gradients."""
    from repro.models.attention import attention, chunked_attention

    B, S, H, KV, D = 1, 32, 2, 2, 8
    q = jax.random.normal(jax.random.key(1), (B, S, H, D))
    k = jax.random.normal(jax.random.key(2), (B, S, KV, D))
    v = jax.random.normal(jax.random.key(3), (B, S, KV, D))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)

    def f_dense(q, k, v):
        return jnp.sum(
            attention(q, k, v, q_positions=pos, k_positions=pos, causal=True) ** 2
        )

    def f_chunk(q, k, v):
        return jnp.sum(
            chunked_attention(
                q, k, v, q_positions=pos, k_positions=pos, causal=True,
                q_chunk=8, k_chunk=8,
            ) ** 2
        )

    g1 = jax.grad(f_dense, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_chunk, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)
