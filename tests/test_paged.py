"""Paged KV cache + chunked prefill: allocator invariants, model-level
dense/paged parity, engine token parity on a mixed-length workload, KV
memory accounting, checkpoint state, and dense-path regressions
(_bucket overflow, _scatter_cache edge shapes)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core import (
    BalancedLagrangianPolicy,
    CostModel,
    GlobalQueueScheduler,
    PrefillFirstPolicy,
    build_clients,
)
from repro.core.types import Request
from repro.data import WorkloadSpec, gsm8k_like_workload
from repro.models.layers import init_params
from repro.models.transformer import TransformerLM
from repro.serving.engine import Engine, EngineConfig, _bucket
from repro.serving.kv_slots import BlockAllocator, PagedSlotManager, _scatter_cache

CFG = ArchConfig(
    name="demo", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=256,
)
# mixed prompt lengths: short conversational next to long-document prompts —
# the workload shape the dense row-per-slot layout over-allocates worst on
MIXED_SPEC = WorkloadSpec(
    n_requests=10, input_mean=30, input_std=20, output_mean=10,
    output_std=6, output_max=16, input_max=60,
)
CM = CostModel(level_caps=(32, 64, 128))


@pytest.fixture(scope="module")
def model_and_params():
    model = TransformerLM(CFG)
    params = init_params(jax.random.key(0), model.param_defs())
    return model, params


def _engine(model, params, layout, **kw):
    eng = Engine(
        model, params,
        EngineConfig(
            n_slots=4, max_len=80, prefill_seq_buckets=(32, 64),
            kv_layout=layout, **kw,
        ),
    )
    eng.profiler.cost_model = CM
    return eng


# --------------------------------------------------------------------------- #
# BlockAllocator                                                              #
# --------------------------------------------------------------------------- #
def test_allocator_allocate_free_cycle():
    a = BlockAllocator(num_pages=8, page_size=16)
    assert a.pages_for(1) == 1 and a.pages_for(16) == 1 and a.pages_for(17) == 2
    p1 = a.allocate(3)
    p2 = a.allocate(2)
    assert len(set(p1) | set(p2)) == 5          # no page handed out twice
    assert a.num_free == 3 and a.num_used == 5
    a.free(p1)
    assert a.num_free == 6
    with pytest.raises(RuntimeError):
        a.free(p1)                               # double free
    with pytest.raises(RuntimeError):
        a.allocate(7)                            # exhaustion
    a.free(p2)
    assert a.num_free == 8


def test_paged_slot_manager_reserve_release(model_and_params):
    model, _ = model_and_params
    mgr = PagedSlotManager(model, n_slots=4, max_len=64, page_size=16, num_pages=8)
    mgr.reserve(0, 40)                           # 3 pages
    assert mgr.allocator.num_used == 3
    row = np.asarray(mgr.cache["block_tables"][0])
    assert (row[:3] >= 0).all() and (row[3:] == -1).all()
    assert mgr.kv_bytes_in_use() > 0
    mgr.bind(0, Request(rid=0, n_prefill=8, n_decode=4))
    mgr.release(0)
    assert mgr.allocator.num_used == 0
    assert (np.asarray(mgr.cache["block_tables"][0]) == -1).all()
    assert int(mgr.cache["length"][0]) == 0


# --------------------------------------------------------------------------- #
# Model-level parity: chunked paged prefill + paged decode == dense           #
# --------------------------------------------------------------------------- #
@pytest.mark.slow
def test_paged_chunked_prefill_and_decode_match_dense(model_and_params):
    model, params = model_and_params
    rng = np.random.default_rng(0)
    lens = [13, 7, 21]
    prompts = [rng.integers(1, CFG.vocab_size, size=n).astype(np.int32) for n in lens]

    n_slots = 4
    dense = model.cache_init(n_slots, 32)
    toks = np.zeros((n_slots, 32), np.int32)
    for i, p in enumerate(prompts):
        toks[i, : len(p)] = p
    logits_d, dense = model.prefill(
        params, jnp.asarray(toks), dense,
        lengths=jnp.asarray(lens + [1], jnp.int32),
    )

    page_size, num_pages, mb = 8, 24, 8
    cache = model.paged_cache_init(num_pages, page_size, n_slots, mb)
    bt = np.full((n_slots, mb), -1, np.int32)
    nxt = 0
    for i, n in enumerate(lens):
        need = -(-(n + 8) // page_size)
        bt[i, :need] = range(nxt, nxt + need)
        nxt += need
    cache["block_tables"] = jnp.asarray(bt)

    chunk = 8
    done = [0] * 3
    logits_p = [None] * 3
    while any(d < n for d, n in zip(done, lens)):
        rows = [i for i in range(3) if done[i] < lens[i]]
        b = len(rows)
        t = np.zeros((b, chunk), np.int32)
        sid = np.zeros(b, np.int32)
        st = np.zeros(b, np.int32)
        cl = np.zeros(b, np.int32)
        for r, i in enumerate(rows):
            n = min(chunk, lens[i] - done[i])
            t[r, :n] = prompts[i][done[i] : done[i] + n]
            sid[r], st[r], cl[r] = i, done[i], n
        lg, cache = model.prefill_chunk(
            params, jnp.asarray(t), cache, jnp.asarray(sid),
            jnp.asarray(st), jnp.asarray(cl),
        )
        for r, i in enumerate(rows):
            done[i] += int(cl[r])
            if done[i] >= lens[i]:
                logits_p[i] = np.asarray(lg[r])

    for i in range(3):
        np.testing.assert_allclose(
            np.asarray(logits_d[i]), logits_p[i], rtol=2e-5, atol=2e-5
        )

    # decode: 4 steps, slot 3 inactive (its paged row must not write)
    active = jnp.asarray([True, True, True, False])
    pend = np.argmax(np.asarray(logits_d)[:3], axis=1).astype(np.int32)
    pend_p = pend.copy()
    dtoks = np.zeros(4, np.int32)
    ptoks = np.zeros(4, np.int32)
    for _ in range(4):
        dtoks[:3], ptoks[:3] = pend, pend_p
        ld, dense = model.decode_step(params, jnp.asarray(dtoks), dense)
        lp, cache = model.decode_step(params, jnp.asarray(ptoks), cache, active=active)
        np.testing.assert_allclose(
            np.asarray(ld)[:3], np.asarray(lp)[:3], rtol=2e-5, atol=2e-5
        )
        pend = np.argmax(np.asarray(ld)[:3], axis=1).astype(np.int32)
        pend_p = np.argmax(np.asarray(lp)[:3], axis=1).astype(np.int32)
        np.testing.assert_array_equal(pend, pend_p)


# --------------------------------------------------------------------------- #
# Engine: paged + chunked serve == dense serve, with less KV memory           #
# --------------------------------------------------------------------------- #
def _serve(eng, seed, policy):
    reqs = gsm8k_like_workload(MIXED_SPEC, seed=seed, known_lengths=True)
    clients = build_clients(4, reqs, None)
    tr = eng.serve(reqs, clients, GlobalQueueScheduler(reqs), policy)
    tr.validate()
    return tr


@pytest.mark.slow
def test_engine_paged_matches_dense_tokens(model_and_params):
    model, params = model_and_params
    eng_d = _engine(model, params, "dense")
    tr_d = _serve(eng_d, 5, PrefillFirstPolicy())
    eng_p = _engine(
        model, params, "paged", page_size=16, prefill_chunk=24, num_pages=16
    )
    tr_p = _serve(eng_p, 5, PrefillFirstPolicy())
    assert eng_d.generated.keys() == eng_p.generated.keys()
    for rid in eng_d.generated:
        assert eng_d.generated[rid] == eng_p.generated[rid], f"rid {rid}"
    # strictly fewer KV bytes than the dense n_slots × max_len layout
    dense_bytes = eng_d.slots.cache["k"].nbytes + eng_d.slots.cache["v"].nbytes
    assert eng_p.slots.kv_bytes_capacity() < dense_bytes
    # all pages returned to the pool at drain
    assert eng_p.slots.allocator.num_free == eng_p.slots.allocator.num_pages
    # chunked prefill really split prompts: some stages carry partial slots
    assert any(s.busy_partial for s in tr_p.stages)


@pytest.mark.slow
def test_engine_paged_lagrangian_chunk_pricing(model_and_params):
    """The Lagrangian policy must serve a valid trace when the candidate is
    priced per chunk (chunk_tokens set) and interleave decode with chunking
    (the alternating-stage path; mixed-step pricing is covered in
    tests/test_mixed_batch.py)."""
    model, params = model_and_params
    eng = _engine(
        model, params, "paged", page_size=16, prefill_chunk=24, num_pages=16,
        mixed_schedule=False,
    )
    tr = _serve(eng, 6, BalancedLagrangianPolicy())
    assert tr.utilization > 0.2
    kinds = [s.kind.value for s in tr.stages]
    assert "prefill" in kinds and "decode" in kinds


@pytest.mark.slow
def test_engine_paged_checkpoint_roundtrip(model_and_params, tmp_path):
    from repro.checkpoint import restore_checkpoint, save_checkpoint

    model, params = model_and_params
    eng = _engine(
        model, params, "paged", page_size=16, prefill_chunk=24, num_pages=16
    )
    _serve(eng, 3, PrefillFirstPolicy())
    eng._budget_shift = 2
    eng.straggler_events = 5
    state = eng.state_dict()
    save_checkpoint(tmp_path, 1, state)
    eng2 = _engine(
        model, params, "paged", page_size=16, prefill_chunk=24, num_pages=16
    )
    restored, _ = restore_checkpoint(tmp_path, 1, eng2.state_dict())
    reqs = gsm8k_like_workload(MIXED_SPEC, seed=3, known_lengths=True)
    eng2.load_state_dict(restored, {r.rid: r for r in reqs})
    # straggler-mitigation state survives the round trip (regression: it
    # used to be dropped, so a restored engine forgot it was throttling)
    assert eng2._budget_shift == 2
    assert eng2.straggler_events == 5
    for a, b in zip(
        jax.tree_util.tree_leaves(eng.slots.cache),
        jax.tree_util.tree_leaves(eng2.slots.cache),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # host-side page bookkeeping rebuilt from the device block table
    assert eng2.slots.tables == eng.slots.tables
    assert eng2.slots.allocator.num_free == eng.slots.allocator.num_free


def test_engine_paged_checkpoint_restores_mid_chunk_state(model_and_params):
    """A checkpoint taken while a prompt is half-prefilled must restore the
    chunk cursor and page ownership — otherwise the request is forgotten and
    its pages leak (regression)."""
    model, params = model_and_params
    eng = _engine(
        model, params, "paged", page_size=16, prefill_chunk=16, num_pages=16
    )
    req = Request(rid=0, n_prefill=40, n_decode=4)
    clients = build_clients(4, [req], None)
    eng._start_chunked_batch([(clients[0], req)], 0, 0.0)
    eng._run_chunk_round()                        # done = 16 < 40
    assert eng._chunking[0].done == 16
    state = eng.state_dict()
    eng2 = _engine(
        model, params, "paged", page_size=16, prefill_chunk=16, num_pages=16
    )
    eng2.load_state_dict(state, {0: req})
    assert 0 in eng2._chunking
    assert eng2._chunking[0].done == 16
    assert eng2._chunking[0].req is req
    assert eng2.slots.tables[0] == eng.slots.tables[0]
    assert eng2.slots.allocator.num_free == eng.slots.allocator.num_free


def test_engine_paged_admits_while_chunking(model_and_params):
    """Idle slots must keep admitting new prompts while a long prompt is
    mid-chunk — a prefill stage may carry a finishing short prompt (busy)
    alongside the long one still chunking (busy_partial)."""
    model, params = model_and_params
    reqs = [
        Request(rid=0, n_prefill=60, n_decode=12),   # 3 chunks of 24
        Request(rid=1, n_prefill=10, n_decode=12),
        Request(rid=2, n_prefill=10, n_decode=12),
    ]
    eng = _engine(
        model, params, "paged", page_size=16, prefill_chunk=24, num_pages=20
    )
    clients = build_clients(4, reqs, None)
    tr = eng.serve(reqs, clients, GlobalQueueScheduler(reqs), PrefillFirstPolicy())
    tr.validate()
    assert any(
        s.busy and s.busy_partial for s in tr.stages
    ), "short prompts should finish prefill in a stage the long prompt is still chunking"


def test_engine_dense_checkpoint_keeps_straggler_state(model_and_params):
    model, params = model_and_params
    eng = _engine(model, params, "dense")
    eng._budget_shift = 1
    eng.straggler_events = 3
    state = eng.state_dict()
    eng2 = _engine(model, params, "dense")
    reqs = gsm8k_like_workload(MIXED_SPEC, seed=3, known_lengths=True)
    eng2.load_state_dict(state, {r.rid: r for r in reqs})
    assert eng2._budget_shift == 1
    assert eng2.straggler_events == 3


# --------------------------------------------------------------------------- #
# Dense-path regressions riding along                                         #
# --------------------------------------------------------------------------- #
def test_bucket_overflow_raises():
    assert _bucket(30, (32, 64)) == 32
    with pytest.raises(ValueError, match="exceeds the largest bucket"):
        _bucket(65, (32, 64))


def test_engine_rejects_oversize_prompt(model_and_params):
    """A prompt bigger than the top seq bucket used to be silently truncated
    to buckets[-1] and then overflow the padded token write."""
    model, params = model_and_params
    reqs = [Request(rid=0, n_prefill=100, n_decode=4)]
    eng = _engine(model, params, "dense")
    clients = build_clients(4, reqs, None)
    with pytest.raises(ValueError, match="exceeds the largest bucket"):
        eng.serve(reqs, clients, GlobalQueueScheduler(reqs), PrefillFirstPolicy())


def test_scatter_cache_ring_pos_shorter_bucket():
    """Ring 'pos' rows from a shorter prefill bucket must be padded with -1
    (invalid), and rank-1 leaves scattered per batch row."""
    main = {
        "pos": jnp.zeros((4, 8), jnp.int32),
        "length": jnp.zeros((4,), jnp.int32),
    }
    pref = {
        "pos": jnp.asarray([[3, 1], [0, 2]], jnp.int32),   # bucket W=2 < 8
        "length": jnp.asarray([2, 2], jnp.int32),
    }
    out = _scatter_cache(main, pref, jnp.asarray([1, 3], jnp.int32))
    np.testing.assert_array_equal(
        np.asarray(out["pos"][1]), [3, 1, -1, -1, -1, -1, -1, -1]
    )
    np.testing.assert_array_equal(
        np.asarray(out["pos"][3]), [0, 2, -1, -1, -1, -1, -1, -1]
    )
    np.testing.assert_array_equal(np.asarray(out["pos"][0]), np.zeros(8))
    np.testing.assert_array_equal(np.asarray(out["length"]), [0, 2, 0, 2])


def test_scatter_cache_seq_bucket_zero_fills_stale_rows():
    """A shorter seq-bucket prefill must zero the row beyond its prefix so no
    stale K/V from a previous occupant survives."""
    main = {"k": jnp.full((2, 4, 8, 1, 2), 7.0)}          # stale values
    pref = {"k": jnp.ones((2, 2, 4, 1, 2))}               # bucket S=4 < 8
    out = _scatter_cache(main, pref, jnp.asarray([0, 2], jnp.int32))
    k = np.asarray(out["k"])
    assert (k[:, 0, :4] == 1).all() and (k[:, 0, 4:] == 0).all()
    assert (k[:, 2, :4] == 1).all() and (k[:, 2, 4:] == 0).all()
    assert (k[:, 1] == 7).all() and (k[:, 3] == 7).all()   # untouched slots
