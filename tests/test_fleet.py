"""Fleet-scale serving: offline bin-packed partitioning vs round-robin,
cross-replica work stealing, checkpoint/restore of all replicas mid-serve,
and exact 1-replica-Fleet ↔ bare-Engine token parity."""
import copy

import jax
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core import (
    CostModel,
    GlobalQueueScheduler,
    LagrangianPolicy,
    Request,
    build_clients,
)
from repro.models.layers import init_params
from repro.models.transformer import TransformerLM
from repro.serving.engine import Engine, EngineConfig
from repro.serving.fleet import Fleet, FleetConfig

CFG = ArchConfig(
    name="demo", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=256,
)
CM = CostModel(level_caps=(32, 64, 128))
ENGINE_CFG = dict(
    n_slots=2, max_len=64, prefill_seq_buckets=(32,),
    kv_layout="paged", page_size=16, prefill_chunk=16,
)


@pytest.fixture(scope="module")
def model_and_params():
    model = TransformerLM(CFG)
    params = init_params(jax.random.key(0), model.param_defs())
    return model, params


def _fleet(model, params, engine_kw=None, **fc_kw):
    fc_kw.setdefault("n_replicas", 2)
    return Fleet(
        model, params, EngineConfig(**ENGINE_CFG, **(engine_kw or {})),
        FleetConfig(**fc_kw), cost_model=CM,
    )


def _skewed_requests():
    """Long decodes at even rids: round-robin over 2 replicas piles every
    long request onto replica 0 while LPT spreads them."""
    reqs = []
    for rid in range(8):
        if rid % 2 == 0:
            reqs.append(Request(rid=rid, n_prefill=10, n_decode=24))
        else:
            reqs.append(Request(rid=rid, n_prefill=8, n_decode=4))
    return reqs


# --------------------------------------------------------------------------- #
# LPT vs round-robin ordering on a skewed workload                            #
# --------------------------------------------------------------------------- #
def test_lpt_beats_round_robin_on_skewed_workload(model_and_params):
    model, params = model_and_params
    results = {}
    # per-token dispatch (decode_horizon=1, alternating stages) makes every
    # decode round cost the same in both fleets, so the measured makespan
    # ordering reflects ROUND COUNTS — the property under test — instead of
    # how well round-robin's straggler replica happens to amortize fused
    # dispatches on a tiny workload
    engine_kw = dict(decode_horizon=1, mixed_schedule=False)
    for kind, kw in (
        ("rr", dict(assign="round_robin", dispatch="round_robin",
                    work_stealing=False)),
        ("lpt", dict(assign="lpt", dispatch="least_load")),
    ):
        fleet = _fleet(model, params, engine_kw=engine_kw, **kw)
        fleet.serve(_skewed_requests(), LagrangianPolicy)   # warm (compiles)
        report = fleet.serve(_skewed_requests(), LagrangianPolicy)
        results[kind] = (report, fleet.generated, fleet)
    rr, lpt = results["rr"][0], results["lpt"][0]
    # the offline layer's whole point at replica granularity: balanced
    # partitions finish together, round-robin leaves a straggler replica.
    # The fleet makespan at per-token dispatch is the straggler's decode
    # ROUND count × round time — assert the round count (machine-
    # independent; the wall-clock ordering itself is asserted at larger
    # scale in benchmarks/fleet.py, where the margin dwarfs timer noise)
    def straggler_rounds(report):
        return max(sum(s.rounds for s in t.stages) for t in report.traces)

    assert straggler_rounds(lpt) < straggler_rounds(rr)
    # utilization is a ratio of the same measured durations, so uniform
    # machine slowdowns cancel; round-robin's idle replica drags it down
    assert lpt.utilization > rr.utilization
    # LPT's offline partition spread the 4 long requests 2+2 (work stealing
    # may move one later; the partition itself is deterministic), while
    # round-robin piled all 4 onto replica 0
    lpt_parts = results["lpt"][2]._offline_result.assignment
    assert sorted(sum(1 for rid in part if rid % 2 == 0) for part in lpt_parts) \
        == [2, 2]
    rr_parts = results["rr"][2]._offline_result.assignment
    assert sorted(sum(1 for rid in part if rid % 2 == 0) for part in rr_parts) \
        == [0, 4]
    # exact per-request token parity: the assignment must never change what
    # gets generated, only where
    assert results["rr"][1] == results["lpt"][1]


def test_fleet_report_validates_lower_bound_fields(model_and_params):
    model, params = model_and_params
    fleet = _fleet(model, params, assign="lpt")
    report = fleet.serve(_skewed_requests(), LagrangianPolicy)
    report.validate()
    assert report.total_slots == 4
    assert report.lower_bound_s > 0
    assert report.lb_ratio == pytest.approx(
        report.makespan / report.lower_bound_s
    )
    assert 0 < report.utilization <= 1
    s = report.summary()
    assert s["n_replicas"] == 2 and len(s["replica_summaries"]) == 2
    assert s["num_requests"] == 8


# --------------------------------------------------------------------------- #
# Work stealing                                                               #
# --------------------------------------------------------------------------- #
def test_work_steal_produces_identical_tokens_counted_once(model_and_params):
    model, params = model_and_params
    # round-robin sends every long request to replica 0 (3 longs behind 2
    # slots — one stays queued for ~30 rounds) and only 4-token shorts to
    # replica 1, which drains almost immediately and must steal the queued
    # long
    reqs = [
        Request(rid=0, n_prefill=10, n_decode=32),
        Request(rid=1, n_prefill=8, n_decode=4),
        Request(rid=2, n_prefill=10, n_decode=32),
        Request(rid=3, n_prefill=8, n_decode=4),
        Request(rid=4, n_prefill=10, n_decode=32),
        Request(rid=5, n_prefill=8, n_decode=4),
    ]
    fleet = _fleet(
        model, params, assign="round_robin", dispatch="round_robin",
        work_stealing=True,
    )
    # warm serve: first-hit compile costs land in these stage clocks, not
    # the measured ones — cold clocks are so distorted that the steal
    # gate's virtual-time race can resolve either way
    fleet.serve([copy.copy(r) for r in reqs], LagrangianPolicy)
    report = fleet.serve([copy.copy(r) for r in reqs], LagrangianPolicy)
    assert fleet.steal_events >= 1
    # counted once: fleet-level validate rejects double-served requests,
    # and the generated merge rejects double-decoded ones
    report.validate()
    gen = fleet.generated
    assert sorted(gen.keys()) == [0, 1, 2, 3, 4, 5]
    # identical tokens: a bare engine serving the same workload alone
    # produces the same streams (stealing must not change results)
    eng = Engine(model, params, EngineConfig(**ENGINE_CFG))
    eng.profiler.cost_model = CM
    ref_reqs = [copy.copy(r) for r in reqs]
    clients = build_clients(2, ref_reqs, None)
    eng.serve(ref_reqs, clients, GlobalQueueScheduler(ref_reqs),
              LagrangianPolicy())
    assert eng.generated == gen
    # the stolen rid really moved: donor and thief traces partition the set
    stolen = {e["rid"] for e in fleet.steal_log}
    for e in fleet.steal_log:
        thief_rids = {r.rid for r in report.traces[e["to"]].requests}
        assert e["rid"] in thief_rids
    assert stolen


def test_no_stealing_when_disabled(model_and_params):
    model, params = model_and_params
    fleet = _fleet(
        model, params, assign="round_robin", dispatch="round_robin",
        work_stealing=False,
    )
    report = fleet.serve(_skewed_requests(), LagrangianPolicy)
    assert fleet.steal_events == 0
    # round-robin partitions by rid order: replicas keep exactly their own
    assert [sorted(r.rid for r in t.requests) for t in report.traces] == [
        [0, 2, 4, 6], [1, 3, 5, 7],
    ]


# --------------------------------------------------------------------------- #
# Checkpoint / restore of all replicas mid-serve                              #
# --------------------------------------------------------------------------- #
def test_fleet_checkpoint_restore_mid_serve(model_and_params):
    model, params = model_and_params

    def requests():
        return [
            Request(rid=i, n_prefill=10 + 2 * (i % 3), n_decode=8 + 4 * (i % 4))
            for i in range(6)
        ]

    fleet = _fleet(model, params, assign="lpt")
    fleet.begin_serve(requests(), LagrangianPolicy)
    steps = 0
    while steps < 8 and fleet.step():
        steps += 1
    assert any(eng.slots.active_slots or eng._chunking
               for eng in fleet.engines), "checkpoint must be mid-serve"
    state = jax.tree_util.tree_map(np.asarray, fleet.state_dict())
    pre = {rid: list(t) for rid, t in fleet.generated.items()}

    # original continues to completion
    while fleet.step():
        pass
    full = fleet.finish_serve()
    full.validate()
    final = fleet.generated

    # restored fleet continues from the checkpoint on fresh request objects
    fleet2 = _fleet(model, params, assign="lpt")
    reqs2 = {r.rid: r for r in requests()}
    fleet2.load_state_dict(state, reqs2)
    # restored replica caches match the checkpointed ones exactly
    for eng_state, eng2 in zip(state["engines"], fleet2.engines):
        for x, y in zip(
            jax.tree_util.tree_leaves(eng_state["cache"]),
            jax.tree_util.tree_leaves(
                jax.tree_util.tree_map(np.asarray, eng2.slots.cache)
            ),
        ):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    while fleet2.step():
        pass
    report2 = fleet2.finish_serve()          # resumed: skips full validation
    post = fleet2.generated

    # pre-checkpoint tokens + post-restore tokens == the uninterrupted run,
    # per request, with every request counted exactly once
    assert set(pre) | set(post) >= set(final)
    for rid, toks in final.items():
        assert pre.get(rid, []) + post.get(rid, []) == toks, f"rid {rid}"
    # every request finished on exactly one replica in the resumed fleet too
    seen = [r.rid for t in report2.traces for r in t.requests]
    assert len(seen) == len(set(seen))


# --------------------------------------------------------------------------- #
# 1-replica Fleet == bare Engine                                              #
# --------------------------------------------------------------------------- #
def test_single_replica_fleet_matches_bare_engine(model_and_params):
    model, params = model_and_params

    def requests():
        return [
            Request(rid=i, n_prefill=8 + 3 * (i % 3), n_decode=5 + 2 * (i % 4))
            for i in range(6)
        ]

    fleet = _fleet(model, params, n_replicas=1, assign="lpt")
    report = fleet.serve(requests(), LagrangianPolicy)
    report.validate()

    eng = Engine(model, params, EngineConfig(**ENGINE_CFG))
    eng.profiler.cost_model = CM
    reqs = requests()
    # the 1-replica fleet's per-replica queue is its partition sorted
    # longest-first (Algorithm 1) — mirror that exactly
    clients = build_clients(2, reqs, None)
    tr = eng.serve(
        reqs, clients,
        GlobalQueueScheduler(reqs, sort_longest_first=True),
        LagrangianPolicy(),
    )
    tr.validate()
    assert fleet.generated == eng.generated
    # same number of stages of each kind: the fleet layer added no
    # scheduling behavior at n_replicas=1
    fleet_kinds = [s.kind for s in report.traces[0].stages]
    engine_kinds = [s.kind for s in tr.stages]
    assert fleet_kinds == engine_kinds


# --------------------------------------------------------------------------- #
# Scheduler fleet hooks (unit)                                                #
# --------------------------------------------------------------------------- #
def test_arrival_queue_scheduler_fleet_hooks():
    """push must keep the arrival-sort invariant peek/next_arrival early-
    exit on, and steal_longest must only surrender *arrived* requests."""
    from repro.core import ArrivalQueueScheduler

    reqs = [
        Request(rid=0, n_prefill=4, n_decode=2, arrival=0.0),
        Request(rid=2, n_prefill=8, n_decode=2, arrival=2.0),
        Request(rid=4, n_prefill=4, n_decode=2, arrival=4.0),
    ]
    sched = ArrivalQueueScheduler(reqs)
    sched.set_now(2.5)
    sched.push(Request(rid=9, n_prefill=4, n_decode=2, arrival=3.0))
    assert [r.rid for r in sched.queued] == [0, 2, 9, 4]
    assert sched.next_arrival() == 3.0
    # longest ARRIVED request is rid 2 (10 tokens); rids 9/4 are future
    victim = sched.steal_longest()
    assert victim.rid == 2
    sched.steal_longest()                        # rid 0, the last arrived
    assert sched.steal_longest() is None         # futures are not stealable
    assert [r.rid for r in sched.queued] == [9, 4]


def test_global_queue_scheduler_fleet_hooks():
    from repro.core import GlobalQueueScheduler as GQS

    reqs = [Request(rid=i, n_prefill=4, n_decode=4 + i) for i in range(3)]
    sched = GQS(reqs)
    sched.push(Request(rid=9, n_prefill=4, n_decode=50))
    assert sched.steal_longest().rid == 9        # longest by est tokens
    assert sched.pending_count() == 3
    assert [r.rid for r in sched.queued] == [0, 1, 2]


# --------------------------------------------------------------------------- #
# Dispatch policies (unit)                                                    #
# --------------------------------------------------------------------------- #
def test_round_robin_dispatch_cursor_resets_per_serve(model_and_params):
    model, params = model_and_params
    fleet = _fleet(model, params, assign="round_robin", dispatch="round_robin")
    reqs = [Request(rid=i, n_prefill=8, n_decode=4, arrival=0.001 * (i + 1))
            for i in range(3)]
    fleet.begin_serve(reqs, LagrangianPolicy)
    routed = [fleet.dispatcher.choose(fleet, r) for r in reqs]
    assert routed == [0, 1, 0]
    # a fresh serve on the SAME fleet object must route identically
    fleet.begin_serve([Request(rid=i, n_prefill=8, n_decode=4,
                               arrival=0.001 * (i + 1)) for i in range(3)],
                      LagrangianPolicy)
    assert fleet.dispatcher.cursor == 0


def test_least_load_dispatch_prefers_drained_replica(model_and_params):
    model, params = model_and_params
    fleet = _fleet(model, params, assign="lpt", dispatch="least_load")
    # open sessions with an imbalanced offline split: all work on replica 0
    reqs = [Request(rid=i, n_prefill=8, n_decode=20, arrival=0.0)
            for i in range(4)]
    fleet.begin_serve(reqs, LagrangianPolicy)
    loads = [fleet.estimated_load_s(i) for i in range(2)]
    # LPT balanced 4 equal requests 2+2
    assert loads[0] == pytest.approx(loads[1])
    # drain replica 1's queue and route a new arrival — it must go there
    while fleet.engines[1]._sv.scheduler.queued:
        fleet.engines[1]._sv.scheduler.steal_longest()
    late = Request(rid=99, n_prefill=8, n_decode=20, arrival=0.001)
    assert fleet.dispatcher.choose(fleet, late) == 1
